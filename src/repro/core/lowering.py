"""Lowering: AAP `Program` -> register-machine `LoweredProgram` + scan VM.

The interpreter (`core.engine.Subarray.run`) unrolls every micro-op into a
separate traced jnp operation over a dict of named rows, so a 32-bit ripple
add (~384 AAPs) becomes a multi-thousand-op jaxpr that is re-traced per
program shape and never keeps rows resident. The paper's controller (§7) —
like SIMDRAM's µProgram sequencer and the in-DRAM bulk-bitwise execution
engines it inspired — instead drives a *dumb sequencer* over a fixed command
encoding. This module is that lowering:

  * row names are resolved to indices in a single ``(n_rows, ..., words)``
    uint32 **plane tensor** (fixed layout: T0..T3, DCC0, DCC1, C0, C1 at
    indices 0..7, a write sink at 8, D-group rows after, in first-reference
    order), and
  * each AAP/AP command becomes one row of a static ``(n_cmds, 5)`` int32
    **opcode table** ``(kind, src0, src1, src2, aux)`` encoding the full
    activate semantics — n-wordline negation polarity on every source and
    destination, and the destructive write-back of triple-row activation.

Executed by ``run_scan`` — a `jax.lax.scan` virtual machine whose jaxpr is
**constant-size regardless of program length** (the table is scan data, not
structure) and whose jit cache is keyed only by ``(n_cmds, n_rows, words)``
shapes, so structurally distinct programs of the same shape share one
compiled executable — or by the Pallas megakernel (`kernels.vm`), which
holds the whole plane tensor in VMEM for the duration of the program and
writes back only the output rows. Both are bit-identical to the interpreter
on every program (tests/test_lowering.py, tests/test_property_lowering.py).

Command encoding
----------------

``kind`` packs the sense arity and source polarities:
  bit 0      1 = TRA (3-wordline sense, digital majority), 0 = single sense
  bits 2..4  polarity of src0/src1/src2 (1 = n-wordline: complement feeds
             the bitline)

Single-sense commands replicate src0 into src1/src2 so the VM step computes
``maj3`` unconditionally (``maj3(x, x, x) == x``) — no data-dependent branch.

``aux`` packs the write set:
  bits 0..7   pos mask over fixed rows 0..7: row <- sensed value
  bits 8..15  neg mask over fixed rows 0..7: row <- ~sensed value
  bits 16..   index of the (at most one) D/C-group destination row; the
              sink row when the command writes no D/C row

The destructive first-ACTIVATE restore lands in the masks first and the
second ACTIVATE's targets override them at lowering time, preserving the
interpreter's write order. Single-wordline first activates restore their own
sensed value and are elided as the no-ops they are.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.addressing import D_WL, resolve
from repro.core.commands import AAP, AP, Program
from repro.core.engine import BuddyError

# Fixed plane layout: the 8 B/C-group rows, then the write sink, then
# D-group rows in first-reference order.
FIXED_ROWS: Tuple[str, ...] = ("T0", "T1", "T2", "T3", "DCC0", "DCC1",
                               "C0", "C1")
SINK = "__SINK__"
SINK_IDX = len(FIXED_ROWS)          # 8
N_RESERVED = SINK_IDX + 1           # fixed rows + sink
C1_IDX = FIXED_ROWS.index("C1")

KIND_TRA = 1                        # bit 0 of the kind column


@dataclasses.dataclass(frozen=True, eq=False)
class LoweredProgram:
    """A `Program` compiled to plane indices + a static opcode table.

    ``row_names[i]`` names plane row ``i``; ``table`` is the ``(n_cmds, 5)``
    int32 command stream (see module docstring for the encoding). ``reads``
    are the rows whose initial contents the program observes (they must be
    seeded in the plane); ``writes`` are every row the program ever stores
    to (what `engine.execute` validates ``outputs`` against).
    """

    row_names: Tuple[str, ...]
    table: np.ndarray
    reads: Tuple[str, ...]
    writes: Tuple[str, ...]
    comment: str = ""

    @property
    def n_rows(self) -> int:
        return len(self.row_names)

    @property
    def n_cmds(self) -> int:
        return int(self.table.shape[0])

    def row_index(self, name: str) -> int:
        return self.row_names.index(name)


class LoweringError(BuddyError):
    """Raised at lowering time for analog-undefined command sequences —
    the same sequences `Subarray.run` rejects at run time."""


def _sense_wordlines(addr: str) -> Tuple[Tuple[str, str], ...]:
    wls = resolve(addr)
    if len(wls) == 2:
        # Dual addresses (B8-B11) sense two cells from precharged state:
        # majority of 2 is analog-undefined on disagreement — the
        # interpreter raises at run time, the lowerer at compile time.
        raise LoweringError(
            f"{addr} raises 2 wordlines from precharged state; "
            "majority of 2 is undefined on disagreement")
    return wls


def lower(program: Program) -> LoweredProgram:
    """Compile a `Program` into a `LoweredProgram` (memoized on commands)."""
    key = tuple(program.commands)
    cached = _LOWER_CACHE.get(key)
    if cached is not None:
        return cached
    lp = _lower_uncached(program)
    if len(_LOWER_CACHE) > 512:
        _LOWER_CACHE.clear()
    _LOWER_CACHE[key] = lp
    return lp


_LOWER_CACHE: Dict[Tuple, LoweredProgram] = {}


def _lower_uncached(program: Program) -> LoweredProgram:
    names: List[str] = list(FIXED_ROWS) + [SINK]
    index: Dict[str, int] = {n: i for i, n in enumerate(names)}

    def idx_of(row: str) -> int:
        if row not in index:
            index[row] = len(names)
            names.append(row)
        return index[row]

    rows_table: List[Tuple[int, int, int, int, int]] = []
    written: set = set()
    reads: List[str] = []

    def note_read(row: str) -> None:
        if row not in written and row not in reads:
            reads.append(row)

    for cmd in program.commands:
        if isinstance(cmd, AAP):
            addr1, addr2 = cmd.addr1, cmd.addr2
        else:
            assert isinstance(cmd, AP), cmd
            addr1, addr2 = cmd.addr, None
        wls = _sense_wordlines(addr1)

        # sources: polarity-adjusted sensed cells; single sense replicates
        # src0 so maj3(s0, s0, s0) == s0 needs no branch in the VM step
        srcs = [(idx_of(r), pol != D_WL) for r, pol in wls]
        for r, _ in wls:
            note_read(r)
        if len(srcs) == 1:
            srcs = srcs * 3
        kind = (KIND_TRA if len(wls) == 3 else 0) \
            | (srcs[0][1] << 2) | (srcs[1][1] << 3) | (srcs[2][1] << 4)

        # write set: the restore of a multi-wordline first ACTIVATE is
        # destructive (TRA); a single-wordline restore rewrites the value
        # it just sensed and is elided. The second ACTIVATE's targets are
        # forced to the latched result and override on overlap.
        write_pol: Dict[str, bool] = {}
        if len(wls) > 1:
            for r, pol in wls:
                write_pol[r] = pol != D_WL
        if addr2 is not None:
            for r, pol in resolve(addr2):
                write_pol[r] = pol != D_WL
        pos_mask = neg_mask = 0
        dst_idx = SINK_IDX
        for r, negated in write_pol.items():
            written.add(r)
            i = idx_of(r)
            if i < len(FIXED_ROWS):
                if negated:
                    neg_mask |= 1 << i
                else:
                    pos_mask |= 1 << i
            else:
                # D/C-group addresses raise exactly one d-wordline, so at
                # most one non-fixed destination exists per command
                assert dst_idx == SINK_IDX and not negated, (r, cmd)
                dst_idx = i
        aux = (dst_idx << 16) | (neg_mask << 8) | pos_mask
        rows_table.append((kind, srcs[0][0], srcs[1][0], srcs[2][0], aux))

    table = np.asarray(rows_table, dtype=np.int32).reshape(-1, 5)
    return LoweredProgram(
        row_names=tuple(names), table=table, reads=tuple(reads),
        writes=tuple(sorted(written)), comment=program.comment)


# ---------------------------------------------------------------------------
# Plane tensor construction / readout
# ---------------------------------------------------------------------------


def make_plane(lp: LoweredProgram, data: Dict[str, jax.Array],
               row_words: int, batch: Tuple[int, ...] = ()) -> jax.Array:
    """Build the ``(n_rows,) + batch + (row_words,)`` uint32 plane tensor.

    C1 is pre-initialized to all-ones (paper §3.5); every other row not
    present in ``data`` starts zero, matching `engine.Subarray.create`.
    """
    shape = batch + (row_words,)
    zeros = jnp.zeros(shape, jnp.uint32)
    ones = jnp.full(shape, 0xFFFFFFFF, jnp.uint32)
    rows = []
    for i, name in enumerate(lp.row_names):
        if data is not None and name in data:
            rows.append(jnp.broadcast_to(
                jnp.asarray(data[name], jnp.uint32), shape))
        else:
            rows.append(ones if i == C1_IDX else zeros)
    return jnp.stack(rows)


def read_rows(lp: LoweredProgram, plane: jax.Array,
              names: List[str]) -> Dict[str, jax.Array]:
    return {n: plane[lp.row_index(n)] for n in names}


# ---------------------------------------------------------------------------
# The scan VM: one lax.scan step per command, constant-size jaxpr
# ---------------------------------------------------------------------------


def _vm_exec(plane: jax.Array, cmd: jax.Array,
             err: Optional[jax.Array]) -> jax.Array:
    """One command: sense (maj3 of polarity-adjusted sources) + write set.

    Deliberately built from `lax.dynamic_slice` / `dynamic_update_slice`
    rather than gather/scatter (`plane[i]` / `.at[i].set`): XLA compiles
    the slice forms of a single-row access an order of magnitude faster,
    and the VM's whole point is O(1) trace+compile.

    ``err`` (None on the clean path) is this command's ``(4, ...)`` XOR
    fault-mask stack from `core.errors.error_planes`: plane k flips the
    sensed value wherever the operand pattern has k charged cells, so
    injection happens at TRA compute time and faulty values propagate
    through the remaining commands like real analog failures.
    """
    kind = cmd[0]
    full = jnp.uint32(0xFFFFFFFF)
    zero = jnp.uint32(0)

    def src(col: int, polbit: int) -> jax.Array:
        row = jax.lax.dynamic_slice_in_dim(plane, cmd[col], 1, axis=0)
        return row ^ jnp.where((kind >> polbit) & 1, full, zero)

    s0, s1, s2 = src(1, 2), src(2, 3), src(3, 4)
    v = (s0 & s1) | (s1 & s2) | (s2 & s0)       # maj3; == s0 when replicated
    if err is not None:
        # pattern classes partition the bit positions, so exactly one of
        # the four masks applies per bit; non-TRA commands carry all-zero
        # masks (the model zeroes them at generation)
        ones3 = s0 & s1 & s2
        lit = s0 | s1 | s2
        flip = ((err[0] & ~lit) | (err[1] & (lit & ~v))
                | (err[2] & (v & ~ones3)) | (err[3] & ones3))
        v = v ^ flip

    aux = cmd[4]
    pos = aux & 0xFF
    neg = (aux >> 8) & 0xFF
    dst = aux >> 16
    bits = jnp.arange(len(FIXED_ROWS), dtype=jnp.int32)
    sel_shape = (len(FIXED_ROWS),) + (1,) * (plane.ndim - 1)
    pos_sel = (((pos >> bits) & 1) == 1).reshape(sel_shape)
    neg_sel = (((neg >> bits) & 1) == 1).reshape(sel_shape)
    head = plane[:len(FIXED_ROWS)]
    head = jnp.where(pos_sel, v, head)
    head = jnp.where(neg_sel, ~v, head)
    plane = jax.lax.dynamic_update_slice_in_dim(plane, head, 0, axis=0)
    plane = jax.lax.dynamic_update_slice_in_dim(plane, v, dst, axis=0)
    return plane


def _vm_step(plane: jax.Array, cmd: jax.Array):
    return _vm_exec(plane, cmd, None), None


def _vm_step_err(plane: jax.Array, cmd_err):
    cmd, err = cmd_err
    return _vm_exec(plane, cmd, err), None


@jax.jit
def _scan_vm(table: jax.Array, plane: jax.Array) -> jax.Array:
    out, _ = jax.lax.scan(_vm_step, plane, table)
    return out


@jax.jit
def _scan_vm_err(table: jax.Array, plane: jax.Array,
                 errors: jax.Array) -> jax.Array:
    out, _ = jax.lax.scan(_vm_step_err, plane, (table, errors))
    return out


def run_scan(lp: LoweredProgram, plane: jax.Array,
             errors: Optional[jax.Array] = None) -> jax.Array:
    """Execute the opcode table over a plane tensor via the lax.scan VM.

    The jaxpr size is independent of ``n_cmds`` (regression-tested) and the
    jit cache key is purely the argument shapes, so every program lowered to
    the same ``(n_cmds, n_rows, words)`` shape reuses one executable.
    ``errors`` (optional, `core.errors.error_planes`) injects per-command
    TRA fault masks — it rides the scan as data, so the jaxpr stays
    constant-size with injection on too.
    """
    if errors is None:
        return _scan_vm(jnp.asarray(lp.table), plane)
    return _scan_vm_err(jnp.asarray(lp.table), plane,
                        jnp.asarray(errors, jnp.uint32))


def aot_compile_timings(lp: LoweredProgram, data: Dict[str, jax.Array],
                        outputs: Optional[List[str]] = None,
                        backend: str = "scan") -> Dict[str, float]:
    """Trace/compile wall times (us) of the production dispatch executable.

    Lowers and compiles exactly the `_dispatch` computation that
    `execute_lowered` would run for this binding, timing the two stages
    separately (`benchmarks/vm_dispatch.py` reports these against the
    jitted interpreter's O(program length) trace+compile).
    """
    import time

    shapes = [tuple(jnp.asarray(v).shape) for v in data.values()]
    lay = _layout(lp, tuple(sorted(data)),
                  tuple(outputs) if outputs is not None else None)
    args = (jnp.asarray(lay.table),
            tuple(jnp.asarray(data[k], jnp.uint32) for k in lay.val_names),
            ())
    kw = dict(n_rows=lay.n_rows, out_runs=lay.out_runs,
              row_words=int(max(s[-1] for s in shapes)),
              batch=tuple(np.broadcast_shapes(*(s[:-1] for s in shapes))),
              backend=backend, fixed_idx=())
    t0 = time.perf_counter()
    lowered = _dispatch.lower(*args, **kw)
    t1 = time.perf_counter()
    lowered.compile()
    t2 = time.perf_counter()
    return {"trace_us": (t1 - t0) * 1e6, "compile_us": (t2 - t1) * 1e6}


def scan_vm_jaxpr(lp: LoweredProgram, plane_shape: Tuple[int, ...]):
    """The VM's jaxpr for a given plane shape (for size regression tests)."""
    table = jax.ShapeDtypeStruct(lp.table.shape, jnp.int32)
    plane = jax.ShapeDtypeStruct(plane_shape, jnp.uint32)
    return jax.make_jaxpr(
        lambda t, p: jax.lax.scan(_vm_step, p, t)[0])(table, plane)


# ---------------------------------------------------------------------------
# One-shot lowered execution (the engine's default path)
# ---------------------------------------------------------------------------


def _coalesce(idx: Tuple[int, ...]) -> Tuple[Tuple[int, int], ...]:
    """Consecutive index runs -> (start, stop) slices (order-preserving)."""
    runs: List[Tuple[int, int]] = []
    for i in idx:
        if runs and runs[-1][1] == i:
            runs[-1] = (runs[-1][0], i + 1)
        else:
            runs.append((i, i + 1))
    return tuple(runs)


@dataclasses.dataclass(frozen=True, eq=False)
class _Layout:
    """A lowered program re-laid-out for one (data rows, outputs) binding.

    Plane rows are renumbered so the seeded data rows form one contiguous
    block right after the reserved rows and the output rows coalesce into
    as few contiguous runs as possible. That makes the dispatch jaxpr
    gather-free: plane build is a 3-piece concatenate, output extraction a
    handful of static slices — the compile cost of the whole dispatch is
    the scan body plus O(1) glue, however many operand planes there are.
    """

    table: np.ndarray               # opcode table over renumbered rows
    # kept host-side on purpose: converting (and caching) a device array
    # here would leak tracers when execute_lowered runs under an outer jit
    val_names: Tuple[str, ...]      # data rows, in plane-block order
    out_runs: Tuple[Tuple[int, int], ...]   # coalesced output row slices
    out_names: Tuple[str, ...]
    n_rows: int


_LAYOUT_CACHE: Dict[Tuple, Tuple[LoweredProgram, _Layout]] = {}


def _layout(lp: LoweredProgram, data_names: Tuple[str, ...],
            outputs: Optional[Tuple[str, ...]]) -> _Layout:
    key = (id(lp), data_names, outputs)
    hit = _LAYOUT_CACHE.get(key)
    if hit is not None and hit[0] is lp:
        return hit[1]
    index = {n: i for i, n in enumerate(lp.row_names)}
    present = set(data_names)
    seeded = [n for n in lp.row_names[N_RESERVED:] if n in present]
    out_names = (tuple(o for o in outputs if o in index)
                 if outputs is not None
                 else tuple(n for n in lp.row_names if n != SINK))
    # renumber: reserved rows keep indices 0..8 (the fixed-row write masks
    # and the sink are hard-coded there), data rows next, then output rows
    # not already seeded, then the rest
    order = list(range(N_RESERVED))
    order += [index[n] for n in seeded]
    taken = set(order)
    for o in out_names:
        if index[o] not in taken:
            order.append(index[o])
            taken.add(index[o])
    order += [i for i in range(lp.n_rows) if i not in taken]
    remap = np.empty(lp.n_rows, dtype=np.int32)
    remap[np.asarray(order, dtype=np.int32)] = np.arange(lp.n_rows,
                                                         dtype=np.int32)
    table = lp.table.copy()
    table[:, 1:4] = remap[table[:, 1:4]]
    aux = table[:, 4]
    table[:, 4] = (remap[aux >> 16] << 16) | (aux & 0xFFFF)
    layout = _Layout(
        table=table, val_names=tuple(seeded),
        out_runs=_coalesce(tuple(int(remap[index[o]]) for o in out_names)),
        out_names=out_names, n_rows=lp.n_rows)
    if len(_LAYOUT_CACHE) > 1024:
        _LAYOUT_CACHE.clear()
    _LAYOUT_CACHE[key] = (lp, layout)
    return layout


def weight_counts(counts: jax.Array) -> jax.Array:
    """``sum_j 2**j * counts[j]`` over the leading plane axis, in float32.

    The shared aggregate-mode weighting for fused-reduction dispatches
    (x64 is off, so exact int64 shifts are unavailable in-jit; exact-big-
    integer consumers weight ``reduce="popcount"`` counts host-side with
    Python ints — see `service.scheduler`)."""
    n_out = counts.shape[0]
    weights = jnp.asarray([float(1 << j) for j in range(n_out)],
                          jnp.float32).reshape(
                              (n_out,) + (1,) * (counts.ndim - 1))
    return jnp.sum(counts.astype(jnp.float32) * weights, axis=0)


@functools.partial(jax.jit, static_argnames=(
    "n_rows", "out_runs", "row_words", "batch", "backend", "fixed_idx",
    "reduce"))
def _dispatch(table, vals, fixed_vals=(), errors=None, mask=None, *, n_rows,
              out_runs, row_words, batch, backend, fixed_idx=(), reduce=None):
    """Plane build + VM run + output extraction as ONE compiled dispatch.

    The opcode table is a *traced* argument, so the compiled executable is
    shared by every program whose shapes and layout counts match — only
    ``(n_cmds, n_rows, words)`` and the static slice boundaries key the
    jit cache, not program structure. Thanks to `_Layout` renumbering the
    body is gather-free: concatenate [reserved rows | stacked operand
    planes | zero tail], scan (or megakernel), slice the output runs.
    ``errors`` (also traced; None on the clean path) carries the
    per-command TRA fault masks of `core.errors` into the VM.

    ``reduce`` (static) selects the fused count epilogue: instead of the
    output rows, return their per-plane masked popcounts (``"popcount"``,
    int32) or the float32 weighted sum (``"aggregate"``). On the pallas
    backend the popcount runs INSIDE the megakernel (VMEM-accumulated, no
    output-plane HBM writeback); the scan backend folds the identical
    reduction into this same jitted dispatch. ``mask`` (traced; only with
    a reduce mode) ANDs a per-word mask into every counted row.
    """
    shape = batch + (row_words,)
    tail = n_rows - N_RESERVED - len(vals)
    if vals:
        block = jnp.concatenate(
            [jnp.broadcast_to(v, (1,) + shape) for v in vals])
        plane = jnp.pad(block, ((N_RESERVED, tail),) + ((0, 0),) * len(shape))
    else:
        plane = jnp.zeros((n_rows,) + shape, jnp.uint32)
    plane = plane.at[C1_IDX].set(jnp.full(shape, 0xFFFFFFFF, jnp.uint32))
    for i, v in zip(fixed_idx, fixed_vals):     # rare: seeded reserved rows
        plane = plane.at[i].set(jnp.broadcast_to(v, shape))
    if backend == "pallas":
        from repro.kernels.vm import vm_megakernel

        out_idx = tuple(i for a, b in out_runs for i in range(a, b))
        return vm_megakernel(table, plane, out_idx, errors=errors,
                             reduce=reduce, mask=mask)
    if errors is None:
        out_plane, _ = jax.lax.scan(_vm_step, plane, table)
    else:
        out_plane, _ = jax.lax.scan(_vm_step_err, plane, (table, errors))
    rows = jnp.concatenate([out_plane[a:b] for a, b in out_runs])
    if reduce is None:
        return rows
    from repro.ops.popcount import popcount_words

    counts = popcount_words(rows if mask is None else rows & mask, axis=-1)
    return counts if reduce == "popcount" else weight_counts(counts)


def execute_lowered(lp: LoweredProgram, data: Dict[str, jax.Array],
                    row_words: Optional[int] = None,
                    outputs: Optional[List[str]] = None,
                    backend: str = "scan",
                    errors: Optional[jax.Array] = None,
                    reduce: Optional[str] = None,
                    mask: Optional[jax.Array] = None):
    """Run a lowered program over named rows; returns named rows.

    Mirrors `engine.execute`: rows the program references but ``data`` does
    not provide are implicitly zero; rows in ``data`` the program never
    touches pass through unchanged; with ``outputs=None`` the returned dict
    covers exactly the rows the interpreter would return. ``backend`` picks
    the `jax.lax.scan` VM (``"scan"``) or the Pallas megakernel
    (``"pallas"``, `kernels.vm`), which streams the plane through VMEM
    block by block and loops the command table on-chip. Either way the
    whole call — plane build, program execution, output extraction — is
    one jitted dispatch.

    ``errors`` injects seeded TRA fault masks (`core.errors.error_planes`,
    shape ``(n_cmds, 4[, *batch], row_words)``) at compute time; masks are
    indexed by command position, so the `_Layout` row renumbering below
    never changes where a fault lands.

    ``reduce`` requests the fused count epilogue instead of output rows:
      * ``"popcount"`` — the dict maps each output name to its per-plane
        int32 popcount (shape ``batch``); on the pallas backend the count
        accumulates in VMEM inside the megakernel and NO output plane is
        written to HBM.
      * ``"aggregate"`` — returns (not a dict) the ``batch``-shaped
        float32 ``sum_j 2**j * popcount(OUT_j)`` over the requested
        outputs in order (`weight_counts`).
    ``mask`` (reduce modes only) ANDs a per-word uint32 mask into every
    counted row before popcounting — the catalog tail mask, or any shape
    broadcastable against the output rows (e.g. per-bank mask shards).
    """
    if backend not in ("scan", "pallas"):
        raise ValueError(f"unknown lowered backend {backend!r}")
    if reduce not in (None, "popcount", "aggregate"):
        raise ValueError(f"unknown reduce mode {reduce!r}")
    if mask is not None and reduce is None:
        raise ValueError("mask= is only meaningful with a reduce mode")
    # the plane's batch shape is the broadcast of every row's batch shape
    # (right-aligned, like the interpreter's per-op jnp broadcasting):
    # batched operands may be (..., X, W) while other rows are (W,)
    shapes = [tuple(jnp.asarray(v).shape) for v in data.values()]
    if row_words is None:
        row_words = int(max(s[-1] for s in shapes))
    batch = tuple(np.broadcast_shapes(*(s[:-1] for s in shapes)))
    lay = _layout(lp, tuple(sorted(data)),
                  tuple(outputs) if outputs is not None else None)
    if errors is not None:
        errors = jnp.asarray(errors, jnp.uint32)
        target = (lp.n_cmds, 4) + batch + (row_words,)
        if errors.shape != target:   # un-batched masks broadcast per query
            errors = jnp.broadcast_to(
                errors.reshape(errors.shape[:2]
                               + (1,) * (len(target) - errors.ndim)
                               + errors.shape[2:]), target)
    seeded_fixed = tuple(n for n in FIXED_ROWS if n in data)
    out_rows = _dispatch(
        lay.table,
        tuple(jnp.asarray(data[k], jnp.uint32) for k in lay.val_names),
        tuple(jnp.asarray(data[n], jnp.uint32) for n in seeded_fixed),
        errors,
        None if mask is None else jnp.asarray(mask, jnp.uint32),
        n_rows=lay.n_rows, out_runs=lay.out_runs,
        row_words=row_words, batch=batch, backend=backend,
        fixed_idx=tuple(FIXED_ROWS.index(n) for n in seeded_fixed),
        reduce=reduce)
    if reduce == "aggregate":
        return out_rows                 # (batch,) float32 weighted sum
    result = {o: out_rows[k] for k, o in enumerate(lay.out_names)}
    passthrough = outputs if outputs is not None else data
    for name in passthrough:
        if name not in result and name in data:
            row = jnp.asarray(data[name], jnp.uint32)
            if reduce == "popcount":
                # count passthrough rows the same way the VM epilogue
                # counts written rows (rare: a requested output the
                # program never writes)
                from repro.ops.popcount import popcount_words

                row = popcount_words(row if mask is None else row & mask,
                                     axis=-1)
            result[name] = row
    return result
