"""Bit-serial arithmetic microprograms over vertical bit planes.

Buddy's triple-row activation *is* the MAJ(a, b, c) primitive that SIMDRAM
(Hajinazar et al., 2021) composes into full adders: for operands laid out
vertically (one D-group row per bit position, `ops.predicate.VerticalColumn`),
an n-bit ADD is n full-adder steps where

    sum_j   = a_j XOR b_j XOR carry      (two Fig. 8 XOR programs)
    carry'  = MAJ(a_j, b_j, carry)       (one native TRA — `maj3_program`)

and every value in the row computes simultaneously — one AAP sequence per
*bit position*, not per element. This module is the microprogram library for
that layer: ripple-carry ADD, two's-complement SUB, constant/column LESS-THAN
(as fusable `Expr` DAGs riding `compile_expr_fused`), and the plane-readout
program behind SUM aggregation. Emitted programs run unchanged through
`core.engine.execute` (single subarray or `n_banks=` bank-parallel) and are
minimized by the same dead-temp peephole as the boolean compiler.

Cost shape (pre-peephole, n-bit operands): ADD is `11 + 18*(n-2) + 14`
commands (LSB needs no carry-in, MSB no carry-out), SUB adds one NOT per
middle bit for the ~b operand; both are O(n) AAP sequences evaluating 65536
elements per row-block.
"""
from __future__ import annotations

import dataclasses
import re
from typing import List, Optional

from repro.core.commands import Command, Program
from repro.core.compiler import (CompileResult, Expr, and_program,
                                 compile_expr_fused, copy_program,
                                 maj3_program, not_program, optimize_program,
                                 or_program, xnor_program, xor_program,
                                 _cmd_addrs)


# Plane names generated from a prefix must stay clear of the reserved
# B/C-group *addresses* and designated rows: a prefix of "B" would generate
# "B0", which the address map resolves to designated row T0, silently
# reading control state instead of the operand plane.
_RESERVED_PLANE_RE = re.compile(r"^(B\d+|C[01]|T[0-3]|DCC[01])$")


def _check_prefix(prefix: str, n_bits: int) -> None:
    for j in (0, max(0, n_bits - 1)):
        name = f"{prefix}{j}"
        if _RESERVED_PLANE_RE.match(name):
            raise ValueError(
                f"plane prefix {prefix!r} generates reserved address "
                f"{name!r}; pick a non-colliding prefix")


@dataclasses.dataclass
class ArithResult:
    """A compiled multi-output arithmetic program.

    `outputs[j]` is the row holding result bit-plane j (LSB-first), so the
    integer result of element i is sum_j 2**j * bit(outputs[j], i).
    """

    program: Program
    outputs: List[str]
    n_temp_rows: int


def rename_rows(program: Program, mapping: dict) -> Program:
    """Rewrite D-group row names in a program (identity for B/C addresses).

    Lets one compiled microprogram serve any plane naming scheme — the
    service planner renames the library's X/Y operand planes to canonical
    IN0..IN{2n-1} so arithmetic plans share the boolean plan cache.
    """
    from repro.core.commands import AAP, AP

    def m(a: str) -> str:
        return mapping.get(a, a)

    cmds: List[Command] = [
        AAP(m(c.addr1), m(c.addr2)) if isinstance(c, AAP) else AP(m(c.addr))
        for c in program.commands
    ]
    return Program(cmds, program.comment)


def _finish(commands: List[Command], outputs: List[str], comment: str,
            temp_prefix: str) -> ArithResult:
    prog = optimize_program(Program(commands, comment), temp_prefix)
    temps = {a for c in prog.commands for a in _cmd_addrs(c)
             if a.startswith(temp_prefix)}
    return ArithResult(prog, outputs, len(temps))


def ripple_add_program(n_bits: int, a_prefix: str = "X", b_prefix: str = "Y",
                       out_prefix: str = "S", sub: bool = False,
                       temp_prefix: str = "TMP") -> ArithResult:
    """n-bit ripple-carry ADD (or two's-complement SUB) over bit planes.

    Reads planes `{a_prefix}j` / `{b_prefix}j`, writes `{out_prefix}j`,
    j = 0..n_bits-1 LSB-first; the result wraps modulo 2**n_bits (the
    carry/borrow out of the MSB is dropped), which makes the same program
    correct for unsigned and for two's-complement signed operands.

    SUB computes a + ~b + 1: the carry-in of 1 cancels the LSB negation
    (a0 ^ ~b0 ^ 1 == a0 ^ b0) and the middle bits use XNOR for the sum half
    and a NOT-staged ~b_j for the MAJ carry — the dual-contact rows make
    the complement a 2-AAP affair instead of a separate pass.
    """
    if n_bits < 1:
        raise ValueError(f"n_bits must be >= 1, got {n_bits}")
    for p in (a_prefix, b_prefix, out_prefix):
        _check_prefix(p, n_bits)
    cmds: List[Command] = []
    outputs = [f"{out_prefix}{j}" for j in range(n_bits)]
    carry = f"{temp_prefix}_c0"
    carry_alt = f"{temp_prefix}_c1"
    nb = f"{temp_prefix}_nb"
    name = "sub" if sub else "add"

    # LSB: carry-in is 0 (add) / 1 (sub); either way no carry row yet.
    a0, b0 = f"{a_prefix}0", f"{b_prefix}0"
    cmds += xor_program(a0, b0, outputs[0]).commands
    if n_bits == 1:
        return _finish(cmds, outputs, f"{name}{n_bits}", temp_prefix)
    if sub:
        # borrow-free = a0 | ~b0  (MAJ(a0, ~b0, 1))
        cmds += not_program(b0, nb).commands
        cmds += or_program(a0, nb, carry).commands
    else:
        cmds += and_program(a0, b0, carry).commands

    for j in range(1, n_bits):
        aj, bj = f"{a_prefix}{j}", f"{b_prefix}{j}"
        half = f"{temp_prefix}_x{j}"            # per-bit name: peephole fuel
        mk_half = xnor_program if sub else xor_program
        cmds += mk_half(aj, bj, half).commands  # a_j ^ b_j (^1 when sub)
        cmds += xor_program(half, carry, outputs[j]).commands
        if j < n_bits - 1:                      # MSB carry-out is dropped
            if sub:
                cmds += not_program(bj, nb).commands
                cmds += maj3_program(aj, nb, carry, carry_alt).commands
            else:
                cmds += maj3_program(aj, bj, carry, carry_alt).commands
            carry, carry_alt = carry_alt, carry
    return _finish(cmds, outputs, f"{name}{n_bits}", temp_prefix)


def ripple_sub_program(n_bits: int, a_prefix: str = "X", b_prefix: str = "Y",
                       out_prefix: str = "S",
                       temp_prefix: str = "TMP") -> ArithResult:
    """a - b as a + ~b + 1 (see `ripple_add_program`)."""
    return ripple_add_program(n_bits, a_prefix, b_prefix, out_prefix,
                              sub=True, temp_prefix=temp_prefix)


def plane_readout_program(n_bits: int, in_prefix: str = "X",
                          out_prefix: str = "S") -> ArithResult:
    """Stage every input plane into an output row (one RowClone AAP each).

    The in-DRAM half of SUM aggregation: SUM(col) = sum_j 2**j *
    popcount(plane_j), so the DRAM's job is only to expose the planes (the
    bit-counting stays host-side, like the paper's §8.1 bitcount). Routing
    the copies through a program keeps SUM on the same plan-cache/
    scheduler/cost-model path as every other query shape.
    """
    if n_bits < 1:
        raise ValueError(f"n_bits must be >= 1, got {n_bits}")
    for p in (in_prefix, out_prefix):
        _check_prefix(p, n_bits)
    cmds: List[Command] = []
    outputs = [f"{out_prefix}{j}" for j in range(n_bits)]
    for j in range(n_bits):
        cmds += copy_program(f"{in_prefix}{j}", outputs[j]).commands
    return ArithResult(Program(cmds, f"readout{n_bits}"), outputs, 0)


# ---------------------------------------------------------------------------
# Comparisons: boolean DAGs over planes -> single-output fused programs
# ---------------------------------------------------------------------------


def lt_const_expr(n_bits: int, k: int,
                  prefix: str = "X") -> Optional[Expr]:
    """`v < k` over planes `{prefix}0..{prefix}{n-1}` as a fusable Expr.

    MSB-first bit-serial compare (BitWeaving §4): where k has a 1, any value
    with a 0 there (and equal above) is smaller. Returns None when the
    predicate is constant-false (k <= 0); a constant-true predicate
    (k >= 2**n_bits) raises — callers own the trivial cases, the expression
    language has no literals.
    """
    _check_prefix(prefix, n_bits)
    if k <= 0:
        return None
    if k >= (1 << n_bits):
        raise ValueError(
            f"v < {k} is constant-true for {n_bits}-bit v; handle trivially")
    lt: Optional[Expr] = None
    eq: Optional[Expr] = None
    for j in range(n_bits - 1, -1, -1):
        pj = Expr.of(f"{prefix}{j}")
        if (k >> j) & 1:
            term = ~pj if eq is None else eq & ~pj
            lt = term if lt is None else lt | term
            eq = pj if eq is None else eq & pj
        else:
            eq = ~pj if eq is None else eq & ~pj
    assert lt is not None
    return lt


def lt_columns_expr(n_bits: int, a_prefix: str = "X",
                    b_prefix: str = "Y") -> Expr:
    """`a < b` element-wise over two plane sets as a fusable Expr DAG.

    lt = OR_j (eq_above_j & ~a_j & b_j) with eq_above the running XNOR
    chain; shared sub-DAGs (each eq prefix) are CSE'd by the compiler and
    the ~a_j & b_j terms fuse to ANDNOT, so the whole compare is one
    minimized AAP program.
    """
    if n_bits < 1:
        raise ValueError(f"n_bits must be >= 1, got {n_bits}")
    for p in (a_prefix, b_prefix):
        _check_prefix(p, n_bits)
    lt: Optional[Expr] = None
    eq: Optional[Expr] = None
    for j in range(n_bits - 1, -1, -1):
        aj, bj = Expr.of(f"{a_prefix}{j}"), Expr.of(f"{b_prefix}{j}")
        term = ~aj & bj if eq is None else eq & ~aj & bj
        lt = term if lt is None else lt | term
        if j > 0:                                # eq unused after the LSB
            eqj = ~(aj ^ bj)
            eq = eqj if eq is None else eq & eqj
    assert lt is not None
    return lt


def compile_lt_const(n_bits: int, k: int, dst: str = "OUT",
                     prefix: str = "X") -> Optional[CompileResult]:
    """Fused single-output program for `v < k` (None if constant-false)."""
    e = lt_const_expr(n_bits, k, prefix)
    return None if e is None else compile_expr_fused(e, dst)


def compile_lt_columns(n_bits: int, dst: str = "OUT", a_prefix: str = "X",
                       b_prefix: str = "Y") -> CompileResult:
    """Fused single-output program for element-wise `a < b`."""
    return compile_expr_fused(lt_columns_expr(n_bits, a_prefix, b_prefix),
                              dst)
