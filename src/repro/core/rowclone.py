"""RowClone cost model (paper §3.5) + placement-aware copy planning.

RowClone-FPM (Fast Parallel Mode): intra-subarray copy via back-to-back
ACTIVATEs — in Buddy this *is* an AAP (49/80 ns).
RowClone-PSM (Pipelined Serial Mode): inter-bank copy over the shared internal
bus — ~1 KB granule reads overlapped with writes; ~1.28 us for an 8 KB row
(the paper's "copy ~ 1 us" and the §6.2.2 dispatch threshold both use this).
"""
from __future__ import annotations

import dataclasses
from enum import Enum


class CopyMode(Enum):
    FPM = "fpm"   # same subarray
    PSM = "psm"   # cross-bank via internal bus
    CHANNEL = "channel"  # different module: plain DDR read+write


@dataclasses.dataclass(frozen=True)
class RowCloneModel:
    fpm_ns: float = 49.0          # one (optimized) AAP
    psm_internal_bus_gbps: float = 6.4   # 64-bit @ 800 MHz
    row_bytes: int = 8192
    channel_bw_gbps: float = 12.8

    def copy_ns(self, mode: CopyMode) -> float:
        if mode == CopyMode.FPM:
            return self.fpm_ns
        if mode == CopyMode.PSM:
            return self.row_bytes / self.psm_internal_bus_gbps  # 1280 ns
        return 2 * self.row_bytes / self.channel_bw_gbps


DEFAULT_ROWCLONE = RowCloneModel()


def classify_copy(src_subarray: int, src_bank: int,
                  dst_subarray: int, dst_bank: int) -> CopyMode:
    if src_bank == dst_bank and src_subarray == dst_subarray:
        return CopyMode.FPM
    return CopyMode.PSM


def op_latency_with_placement(n_fpm_aap: int, n_psm_copies: int,
                              model: RowCloneModel = DEFAULT_ROWCLONE,
                              aap_ns: float = 49.0) -> float:
    """Latency of a Buddy op whose operand staging needs PSM copies.

    §3.5: with 3 PSM copies Buddy is slower than the CPU — §6.2.2 dispatches
    those to the CPU instead (see `core.isa`)."""
    return n_fpm_aap * aap_ns + n_psm_copies * model.copy_ns(CopyMode.PSM)
