"""Compile bitwise operations/expressions to AAP programs (paper Fig. 8).

Primitive op programs are the paper's exact command sequences. The expression
compiler lowers arbitrary boolean expression DAGs over D-group rows to AAP
sequences through temporary D-rows, with common-subexpression and dead-store
elimination (the "standard compiler techniques" of §5.2).

On top of that sits the **fusion pass** (`compile_expr_fused`): a
SIMDRAM-style minimizer that (a) applies the boolean-algebra shrink rules
(idempotence `a & a -> a`, absorption `a | (a & b) -> a`, double negation)
so degenerate inputs cost one RowClone copy instead of full programs,
(b) rewrites composite sub-DAGs into the cheapest native primitive
(`~(a^b)` -> one XNOR program instead of XOR+NOT, the 3-AND/2-OR majority
form -> one TRA, `a & ~b` -> a fused ANDNOT that rides the dual-contact
negation) and (c) runs a peephole pass over the
emitted command stream that forwards values through dead temporary D-rows so
intermediates stay in the B-group designated rows instead of bouncing
through D-group scratch. Fused programs compute bit-identical results and
are never longer than unfused ones (shorter-of-both by construction), with
strictly fewer AAPs whenever a rewrite or forwarding applies (asserted by
tests/test_compiler.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import addressing
from repro.core.commands import AAP, AP, Command, Program

# ---------------------------------------------------------------------------
# Fig. 8 primitive programs
# ---------------------------------------------------------------------------


def copy_program(src: str, dst: str) -> Program:
    """RowClone-FPM copy expressed as a single AAP (§3.5)."""
    return Program([AAP(src, dst)], f"{dst} = {src}")


def zero_program(dst: str) -> Program:
    return Program([AAP("C0", dst)], f"{dst} = 0")


def one_program(dst: str) -> Program:
    return Program([AAP("C1", dst)], f"{dst} = 1")


def not_program(di: str, dk: str) -> Program:
    # §5.2: ACTIVATE Di; ACTIVATE B5; PRECHARGE; ACTIVATE B4; ACTIVATE Dk; PRE
    return Program(
        [AAP(di, "B5"),   # DCC0 = !Di  (n-wordline captures negation)
         AAP("B4", dk)],  # Dk = DCC0
        f"{dk} = not {di}",
    )


def _and_or(di: str, dj: str, dk: str, ctrl: str, name: str) -> Program:
    return Program(
        [AAP(di, "B0"),     # T0 = Di
         AAP(dj, "B1"),     # T1 = Dj
         AAP(ctrl, "B2"),   # T2 = 0 (and) / 1 (or)
         AAP("B12", dk)],   # TRA(T0,T1,T2) -> Dk
        f"{dk} = {di} {name} {dj}",
    )


def and_program(di: str, dj: str, dk: str) -> Program:
    return _and_or(di, dj, dk, "C0", "and")


def or_program(di: str, dj: str, dk: str) -> Program:
    return _and_or(di, dj, dk, "C1", "or")


def _nand_nor(di: str, dj: str, dk: str, ctrl: str, name: str) -> Program:
    return Program(
        [AAP(di, "B0"),
         AAP(dj, "B1"),
         AAP(ctrl, "B2"),
         AAP("B12", "B5"),  # DCC0 = !(TRA result)
         AAP("B4", dk)],    # Dk = DCC0
        f"{dk} = {di} {name} {dj}",
    )


def nand_program(di: str, dj: str, dk: str) -> Program:
    return _nand_nor(di, dj, dk, "C0", "nand")


def nor_program(di: str, dj: str, dk: str) -> Program:
    return _nand_nor(di, dj, dk, "C1", "nor")


def _xor_xnor(di: str, dj: str, dk: str, c_init: str, c_final: str,
              name: str) -> Program:
    # xor:  T1 = !Di & Dj ; T0 = Di & !Dj ; Dk = T0 | T1
    # xnor: T1 = !Di | Dj ; T0 = Di | !Dj ; Dk = T0 & T1
    # (same skeleton; control rows swapped — paper: "or/nor/xnor can be
    #  implemented by appropriately modifying the control rows")
    return Program(
        [AAP(di, "B8"),        # DCC0 = !Di, T0 = Di
         AAP(dj, "B9"),        # DCC1 = !Dj, T1 = Dj
         AAP(c_init, "B10"),   # T2 = T3 = 0 (xor) / 1 (xnor)
         AP("B14"),            # T1 = TRA(DCC0, T1, T2)
         AP("B15"),            # T0 = TRA(DCC1, T0, T3)
         AAP(c_final, "B2"),   # T2 = 1 (xor) / 0 (xnor)
         AAP("B12", dk)],      # Dk = TRA(T0, T1, T2)
        f"{dk} = {di} {name} {dj}",
    )


def xor_program(di: str, dj: str, dk: str) -> Program:
    return _xor_xnor(di, dj, dk, "C0", "C1", "xor")


def xnor_program(di: str, dj: str, dk: str) -> Program:
    return _xor_xnor(di, dj, dk, "C1", "C0", "xnor")


def maj3_program(da: str, db: str, dc: str, dk: str) -> Program:
    """Native TRA majority — the hardware's actual primitive, exposed.

    Not in the paper's Fig. 8 but free given the same address map; we use it
    for majority-vote gradient aggregation (k=3) and as a paper-plus op.
    """
    return Program(
        [AAP(da, "B0"),
         AAP(db, "B1"),
         AAP(dc, "B2"),
         AAP("B12", dk)],
        f"{dk} = maj({da},{db},{dc})",
    )


def andnot_program(di: str, dj: str, dk: str) -> Program:
    """Dk = Di & !Dj in one program — the bitmap-difference workhorse.

    Not a Fig. 8 entry, but free given the same address map: the DCC
    n-wordline captures !Dj on the way in, so the whole op is 5 AAPs versus
    the 6 (NOT then AND) an unfused compiler emits.
    """
    return Program(
        [AAP(di, "B0"),    # T0 = Di
         AAP(dj, "B5"),    # DCC0 = !Dj
         AAP("B4", "B1"),  # T1 = DCC0 = !Dj
         AAP("C0", "B2"),  # T2 = 0
         AAP("B12", dk)],  # Dk = TRA(Di, !Dj, 0) = Di & !Dj
        f"{dk} = {di} andnot {dj}",
    )


BINARY_PROGRAMS = {
    "and": and_program,
    "or": or_program,
    "nand": nand_program,
    "nor": nor_program,
    "xor": xor_program,
    "xnor": xnor_program,
    "andnot": andnot_program,
}


def op_program(op: str, srcs: Sequence[str], dst: str) -> Program:
    if op == "not":
        (src,) = srcs
        return not_program(src, dst)
    if op == "maj3":
        a, b, c = srcs
        return maj3_program(a, b, c, dst)
    if op == "copy":
        (src,) = srcs
        return copy_program(src, dst)
    if op in BINARY_PROGRAMS:
        a, b = srcs
        return BINARY_PROGRAMS[op](a, b, dst)
    raise ValueError(f"unknown op {op!r}")


# ---------------------------------------------------------------------------
# Expression DAG -> program, with CSE + dead-store elimination
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Expr:
    """Boolean expression node over named D-group rows."""

    op: str                       # 'row' | 'not' | 'and' | ... | 'maj3'
    args: Tuple["Expr", ...] = ()
    row: Optional[str] = None     # for op == 'row'

    # -- sugar --
    def __and__(self, o): return Expr("and", (self, o))
    def __or__(self, o): return Expr("or", (self, o))
    def __xor__(self, o): return Expr("xor", (self, o))
    def __invert__(self): return Expr("not", (self,))

    @staticmethod
    def of(row: str) -> "Expr":
        return Expr("row", row=row)


def maj(a: Expr, b: Expr, c: Expr) -> Expr:
    return Expr("maj3", (a, b, c))


@dataclasses.dataclass
class CompileResult:
    program: Program
    n_temp_rows: int


def expr_key(e: Expr) -> Tuple:
    """Structural identity of an expression node (hash-consing key)."""
    if e.op == "row":
        return ("row", e.row)
    return (e.op,) + tuple(expr_key(a) for a in e.args)


# not(X) folds into X's dual primitive — one program instead of two.
_NOT_DUAL = {"and": "nand", "or": "nor", "xor": "xnor",
             "nand": "and", "nor": "or", "xnor": "xor"}


def _or_leaves(e: Expr) -> List[Expr]:
    if e.op == "or":
        return _or_leaves(e.args[0]) + _or_leaves(e.args[1])
    return [e]


def _match_or_patterns(e: Expr) -> Optional[Expr]:
    """Recognize composite or-trees that collapse to one primitive program.

    (a&b)|(b&c)|(c&a)   -> maj3(a,b,c)      (native TRA, 4 AAPs vs 20)
    andnot(a,b)|andnot(b,a) -> xor(a,b)     (sum-of-products form)
    (a&b)|nor(a,b)      -> xnor(a,b)
    Leaves arrive already fused bottom-up, so the SOP forms appear as
    andnot/nor nodes here.
    """
    leaves = _or_leaves(e)
    if len(leaves) == 3 and all(l.op == "and" for l in leaves):
        by_key: Dict[Tuple, Expr] = {}
        pair_sets = []
        for l in leaves:
            ka, kb = expr_key(l.args[0]), expr_key(l.args[1])
            if ka == kb:
                return None
            by_key[ka], by_key[kb] = l.args[0], l.args[1]
            pair_sets.append(frozenset((ka, kb)))
        keys = sorted(set().union(*pair_sets))
        if len(keys) == 3 and len(set(pair_sets)) == 3:
            x, y, z = (by_key[k] for k in keys)
            return Expr("maj3", (x, y, z))
    if len(leaves) == 2:
        p, q = leaves
        if p.op == q.op == "andnot":
            if (expr_key(p.args[0]) == expr_key(q.args[1])
                    and expr_key(p.args[1]) == expr_key(q.args[0])):
                return Expr("xor", p.args)
        if {p.op, q.op} == {"and", "nor"}:
            a, n = (p, q) if p.op == "and" else (q, p)
            if ({expr_key(a.args[0]), expr_key(a.args[1])}
                    == {expr_key(n.args[0]), expr_key(n.args[1])}):
                return Expr("xnor", a.args)
    return None


def _absorbs(x: Expr, y: Expr, inner: str) -> bool:
    """Does `x op y` collapse to `x` by absorption? `inner` is the dual op.

    Covers the classic law (x | (x & y) = x, x & (x | y) = x) plus the
    post-fusion spelling of the and-form: x | andnot(x, z) = x | (x & ~z)
    = x. Children arrive already fused, so `x & ~z` appears as an andnot
    node here, never as an `and` over a `not`.
    """
    kx = expr_key(x)
    if y.op == inner and kx in (expr_key(y.args[0]), expr_key(y.args[1])):
        return True
    return (inner == "and" and y.op == "andnot"
            and kx == expr_key(y.args[0]))


def _rewrite_node(e: Expr) -> Expr:
    """One rewriting step at a node whose children are already fused."""
    if e.op == "not":
        (a,) = e.args
        if a.op == "not":                        # double negation
            return a.args[0]
        if a.op in _NOT_DUAL:
            return Expr(_NOT_DUAL[a.op], a.args)
    elif e.op == "and":
        x, y = e.args
        if expr_key(x) == expr_key(y):           # idempotence: a & a = a
            return x
        if _absorbs(x, y, "or"):                 # absorption: a & (a | b) = a
            return x
        if _absorbs(y, x, "or"):
            return y
        if x.op == "not" and y.op == "not":      # De Morgan beats 2x NOT
            return Expr("nor", (x.args[0], y.args[0]))
        if y.op == "not":
            return Expr("andnot", (x, y.args[0]))
        if x.op == "not":
            return Expr("andnot", (y, x.args[0]))
    elif e.op == "or":
        x, y = e.args
        if expr_key(x) == expr_key(y):           # idempotence: a | a = a
            return x
        if _absorbs(x, y, "and"):                # absorption: a | (a & b) = a
            return x
        if _absorbs(y, x, "and"):
            return y
        m = _match_or_patterns(e)
        if m is not None:
            return m
        if x.op == "not" and y.op == "not":
            return Expr("nand", (x.args[0], y.args[0]))
    return e


def fuse_expr(expr: Expr) -> Expr:
    """Fusion rewriting: collapse composite sub-DAGs into native primitives.

    Bottom-up, memoized on structural keys so shared subexpressions stay
    shared (CSE in `compile_expr` keys on the same structure). Pure DAG ->
    DAG; semantics preserved (tests assert equality on random inputs).
    """
    memo: Dict[Tuple, Expr] = {}

    def go(e: Expr) -> Expr:
        k = expr_key(e)
        if k in memo:
            return memo[k]
        if e.op != "row":
            e = Expr(e.op, tuple(go(a) for a in e.args))
            while True:
                nxt = _rewrite_node(e)
                if expr_key(nxt) == expr_key(e):
                    break
                e = nxt
        memo[k] = e
        return e

    return go(expr)


def _cmd_addrs(c: Command) -> Tuple[str, ...]:
    return (c.addr1, c.addr2) if isinstance(c, AAP) else (c.addr,)


def _addr_rows(addr: str) -> frozenset:
    return frozenset(r for r, _ in addressing.resolve(addr))


def _cmd_reads(c: Command) -> frozenset:
    # rows whose stored value feeds the sense amps (first ACTIVATE)
    return _addr_rows(c.addr1 if isinstance(c, AAP) else c.addr)


def _cmd_writes(c: Command) -> frozenset:
    # every raised wordline is overwritten with the (polarity-adjusted)
    # sensed value — the first ACTIVATE restores, the second forces
    if isinstance(c, AAP):
        return _addr_rows(c.addr1) | _addr_rows(c.addr2)
    return _addr_rows(c.addr)


def optimize_program(program: Program, temp_prefix: str = "TMP") -> Program:
    """Peephole pass: forward values through dead temporary D-rows.

    AAP(x, t) ... AAP(t, y) with t a temp row used nowhere else becomes
    AAP(x, y) — the sensed value lands in its consumer directly and the
    D-group round-trip (one full AAP, ~49ns) disappears. Safe iff no command
    in between reads or writes any wordline-row of y: the first ACTIVATE
    restores x's rows identically in both versions, t is dead by
    construction, and y's rows were untouched on the gap. Iterates to
    fixpoint so chains of temps collapse.
    """
    cmds: List[Command] = list(program.commands)
    changed = True
    while changed:
        changed = False
        occ: Dict[str, List[int]] = {}
        for idx, c in enumerate(cmds):
            for a in _cmd_addrs(c):
                if a.startswith(temp_prefix):
                    occ.setdefault(a, []).append(idx)
        for t, idxs in occ.items():
            if len(idxs) != 2:
                continue
            i, j = idxs
            ci, cj = cmds[i], cmds[j]
            if not (isinstance(ci, AAP) and isinstance(cj, AAP)):
                continue
            if ci.addr2 != t or cj.addr1 != t:
                continue
            y_rows = _addr_rows(cj.addr2)
            if any(y_rows & (_cmd_reads(c) | _cmd_writes(c))
                   for c in cmds[i + 1:j]):
                continue
            cmds[i] = AAP(ci.addr1, cj.addr2)
            del cmds[j]
            changed = True
            break
    return Program(cmds, program.comment)


def compile_expr(expr: Expr, dst: str, temp_prefix: str = "TMP",
                 fuse: bool = False) -> CompileResult:
    """Lower an expression DAG to an AAP program.

    Strategy: post-order walk with hash-consing (CSE). Each interior node is
    materialized into a temporary D-row via its Fig. 8 primitive program; the
    root is materialized directly into `dst` (dead-store elimination — no
    final copy). Temp rows are reference-counted and recycled so the peak
    temp-row footprint is reported (these consume D-group capacity).

    With `fuse=True` the DAG first goes through `fuse_expr` and the emitted
    command stream through `optimize_program` (see `compile_expr_fused`).
    Both the rewritten and the original DAG are compiled and the shorter
    program wins: a rewrite that breaks CSE sharing (e.g. a subexpression
    consumed both plain and negated) can otherwise pessimize, so the
    fused result is never longer than the unfused one by construction.
    """
    if fuse:
        fused_c = _compile_one(fuse_expr(expr), dst, temp_prefix, True)
        plain_c = _compile_one(expr, dst, temp_prefix, True)
        return fused_c if len(fused_c.program.commands) <= \
            len(plain_c.program.commands) else plain_c
    return _compile_one(expr, dst, temp_prefix, False)


def _compile_one(expr: Expr, dst: str, temp_prefix: str,
                 peephole: bool) -> CompileResult:
    commands: List[Command] = []
    memo: Dict[Tuple, str] = {}
    free_temps: List[str] = []
    n_temps = 0
    refcounts: Dict[Tuple, int] = {}

    key = expr_key

    def count(e: Expr):
        k = key(e)
        refcounts[k] = refcounts.get(k, 0) + 1
        if refcounts[k] == 1 and e.op != "row":
            for a in e.args:
                count(a)

    count(expr)

    def alloc_temp() -> str:
        nonlocal n_temps
        if free_temps:
            return free_temps.pop()
        name = f"{temp_prefix}{n_temps}"
        n_temps += 1
        return name

    def release(row: str):
        if row.startswith(temp_prefix):
            free_temps.append(row)

    def emit(e: Expr, out: Optional[str]) -> str:
        k = key(e)
        if e.op == "row":
            if out is not None and out != e.row:
                commands.extend(copy_program(e.row, out).commands)
                return out
            return e.row
        if k in memo and out is None:
            return memo[k]
        src_rows = [emit(a, None) for a in e.args]
        # rows that die after this op can host the result in-place: every
        # Fig. 8 program stages its sources into designated rows before the
        # final AAP writes the destination, so dst == src is safe.
        dying = [r for a, r in zip(e.args, src_rows)
                 if refcounts[key(a)] == 1 and r.startswith(temp_prefix)]
        if out is not None:
            dst_row = out
        elif dying:
            dst_row = dying[0]
        else:
            dst_row = alloc_temp()
        commands.extend(op_program(e.op, src_rows, dst_row).commands)
        for a, r in zip(e.args, src_rows):
            refcounts[key(a)] -= 1
            if refcounts[key(a)] == 0 and r != dst_row:
                release(r)
        if out is None:
            memo[k] = dst_row
        return dst_row

    emit(expr, dst)
    prog = Program(commands, f"{dst} = <expr>")
    if peephole:
        prog = optimize_program(prog, temp_prefix)
        n_temps = len({a for c in prog.commands for a in _cmd_addrs(c)
                       if a.startswith(temp_prefix)})
    return CompileResult(prog, n_temps)


def compile_expr_fused(expr: Expr, dst: str,
                       temp_prefix: str = "TMP") -> CompileResult:
    """Fusing compiler: `compile_expr` plus DAG rewriting + peephole.

    Never emits more commands than the unfused path (shorter-of-both by
    construction) and strictly fewer whenever a rewrite or dead-temp
    forwarding applies (e.g. `~(a^b)`: 9 -> 7, the 5-op majority form:
    20 -> 4), computing bit-identical results throughout.
    """
    return compile_expr(expr, dst, temp_prefix, fuse=True)


# ---------------------------------------------------------------------------
# Reordering / CSE hooks: DAG surgery primitives the cost-based optimizer
# (`service.optimizer`) builds on. Pure structural helpers — no costs here.
# ---------------------------------------------------------------------------

#: the associative-commutative ops whose operand chains may be reordered
#: without changing the computed value
CHAIN_OPS = ("and", "or", "xor")


def flatten_chain(e: Expr, op: str) -> List[Expr]:
    """Operands of the maximal `op`-chain rooted at `e`, left to right.

    `(a | b) | (c | d)` flattens to `[a, b, c, d]` for op="or"; a node of
    a different op is its own single-element chain. Only valid for the
    associative `CHAIN_OPS`.
    """
    if e.op != op:
        return [e]
    out: List[Expr] = []
    for a in e.args:
        out.extend(flatten_chain(a, op))
    return out


def rebuild_chain(op: str, operands: Sequence[Expr]) -> Expr:
    """Left-deep `op`-tree over `operands` (inverse of `flatten_chain`)."""
    if not operands:
        raise ValueError(f"cannot rebuild an empty {op!r} chain")
    e = operands[0]
    for o in operands[1:]:
        e = Expr(op, (e, o))
    return e


def iter_subexprs(e: Expr) -> List[Expr]:
    """Every distinct sub-DAG of `e` (post-order, deduplicated by key).

    The enumeration the cross-query CSE pass counts over: each structurally
    distinct node appears exactly once even when the DAG shares it.
    """
    seen: Dict[Tuple, None] = {}
    out: List[Expr] = []

    def go(n: Expr):
        k = expr_key(n)
        if k in seen:
            return
        seen[k] = None
        for a in n.args:
            go(a)
        out.append(n)

    go(e)
    return out


def expr_size(e: Expr) -> int:
    """Number of distinct interior (non-leaf) nodes in the DAG."""
    return sum(1 for n in iter_subexprs(e) if n.op != "row")
