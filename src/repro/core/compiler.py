"""Compile bitwise operations/expressions to AAP programs (paper Fig. 8).

Primitive op programs are the paper's exact command sequences. The expression
compiler lowers arbitrary boolean expression DAGs over D-group rows to AAP
sequences through temporary D-rows, with common-subexpression and dead-store
elimination (the "standard compiler techniques" of §5.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.commands import AAP, AP, Command, Program

# ---------------------------------------------------------------------------
# Fig. 8 primitive programs
# ---------------------------------------------------------------------------


def copy_program(src: str, dst: str) -> Program:
    """RowClone-FPM copy expressed as a single AAP (§3.5)."""
    return Program([AAP(src, dst)], f"{dst} = {src}")


def zero_program(dst: str) -> Program:
    return Program([AAP("C0", dst)], f"{dst} = 0")


def one_program(dst: str) -> Program:
    return Program([AAP("C1", dst)], f"{dst} = 1")


def not_program(di: str, dk: str) -> Program:
    # §5.2: ACTIVATE Di; ACTIVATE B5; PRECHARGE; ACTIVATE B4; ACTIVATE Dk; PRE
    return Program(
        [AAP(di, "B5"),   # DCC0 = !Di  (n-wordline captures negation)
         AAP("B4", dk)],  # Dk = DCC0
        f"{dk} = not {di}",
    )


def _and_or(di: str, dj: str, dk: str, ctrl: str, name: str) -> Program:
    return Program(
        [AAP(di, "B0"),     # T0 = Di
         AAP(dj, "B1"),     # T1 = Dj
         AAP(ctrl, "B2"),   # T2 = 0 (and) / 1 (or)
         AAP("B12", dk)],   # TRA(T0,T1,T2) -> Dk
        f"{dk} = {di} {name} {dj}",
    )


def and_program(di: str, dj: str, dk: str) -> Program:
    return _and_or(di, dj, dk, "C0", "and")


def or_program(di: str, dj: str, dk: str) -> Program:
    return _and_or(di, dj, dk, "C1", "or")


def _nand_nor(di: str, dj: str, dk: str, ctrl: str, name: str) -> Program:
    return Program(
        [AAP(di, "B0"),
         AAP(dj, "B1"),
         AAP(ctrl, "B2"),
         AAP("B12", "B5"),  # DCC0 = !(TRA result)
         AAP("B4", dk)],    # Dk = DCC0
        f"{dk} = {di} {name} {dj}",
    )


def nand_program(di: str, dj: str, dk: str) -> Program:
    return _nand_nor(di, dj, dk, "C0", "nand")


def nor_program(di: str, dj: str, dk: str) -> Program:
    return _nand_nor(di, dj, dk, "C1", "nor")


def _xor_xnor(di: str, dj: str, dk: str, c_init: str, c_final: str,
              name: str) -> Program:
    # xor:  T1 = !Di & Dj ; T0 = Di & !Dj ; Dk = T0 | T1
    # xnor: T1 = !Di | Dj ; T0 = Di | !Dj ; Dk = T0 & T1
    # (same skeleton; control rows swapped — paper: "or/nor/xnor can be
    #  implemented by appropriately modifying the control rows")
    return Program(
        [AAP(di, "B8"),        # DCC0 = !Di, T0 = Di
         AAP(dj, "B9"),        # DCC1 = !Dj, T1 = Dj
         AAP(c_init, "B10"),   # T2 = T3 = 0 (xor) / 1 (xnor)
         AP("B14"),            # T1 = TRA(DCC0, T1, T2)
         AP("B15"),            # T0 = TRA(DCC1, T0, T3)
         AAP(c_final, "B2"),   # T2 = 1 (xor) / 0 (xnor)
         AAP("B12", dk)],      # Dk = TRA(T0, T1, T2)
        f"{dk} = {di} {name} {dj}",
    )


def xor_program(di: str, dj: str, dk: str) -> Program:
    return _xor_xnor(di, dj, dk, "C0", "C1", "xor")


def xnor_program(di: str, dj: str, dk: str) -> Program:
    return _xor_xnor(di, dj, dk, "C1", "C0", "xnor")


def maj3_program(da: str, db: str, dc: str, dk: str) -> Program:
    """Native TRA majority — the hardware's actual primitive, exposed.

    Not in the paper's Fig. 8 but free given the same address map; we use it
    for majority-vote gradient aggregation (k=3) and as a paper-plus op.
    """
    return Program(
        [AAP(da, "B0"),
         AAP(db, "B1"),
         AAP(dc, "B2"),
         AAP("B12", dk)],
        f"{dk} = maj({da},{db},{dc})",
    )


BINARY_PROGRAMS = {
    "and": and_program,
    "or": or_program,
    "nand": nand_program,
    "nor": nor_program,
    "xor": xor_program,
    "xnor": xnor_program,
}


def op_program(op: str, srcs: Sequence[str], dst: str) -> Program:
    if op == "not":
        (src,) = srcs
        return not_program(src, dst)
    if op == "maj3":
        a, b, c = srcs
        return maj3_program(a, b, c, dst)
    if op == "copy":
        (src,) = srcs
        return copy_program(src, dst)
    if op in BINARY_PROGRAMS:
        a, b = srcs
        return BINARY_PROGRAMS[op](a, b, dst)
    raise ValueError(f"unknown op {op!r}")


# ---------------------------------------------------------------------------
# Expression DAG -> program, with CSE + dead-store elimination
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Expr:
    """Boolean expression node over named D-group rows."""

    op: str                       # 'row' | 'not' | 'and' | ... | 'maj3'
    args: Tuple["Expr", ...] = ()
    row: Optional[str] = None     # for op == 'row'

    # -- sugar --
    def __and__(self, o): return Expr("and", (self, o))
    def __or__(self, o): return Expr("or", (self, o))
    def __xor__(self, o): return Expr("xor", (self, o))
    def __invert__(self): return Expr("not", (self,))

    @staticmethod
    def of(row: str) -> "Expr":
        return Expr("row", row=row)


def maj(a: Expr, b: Expr, c: Expr) -> Expr:
    return Expr("maj3", (a, b, c))


@dataclasses.dataclass
class CompileResult:
    program: Program
    n_temp_rows: int


def compile_expr(expr: Expr, dst: str, temp_prefix: str = "TMP") -> CompileResult:
    """Lower an expression DAG to an AAP program.

    Strategy: post-order walk with hash-consing (CSE). Each interior node is
    materialized into a temporary D-row via its Fig. 8 primitive program; the
    root is materialized directly into `dst` (dead-store elimination — no
    final copy). Temp rows are reference-counted and recycled so the peak
    temp-row footprint is reported (these consume D-group capacity).
    """
    commands: List[Command] = []
    memo: Dict[Tuple, str] = {}
    free_temps: List[str] = []
    n_temps = 0
    refcounts: Dict[Tuple, int] = {}

    def key(e: Expr) -> Tuple:
        if e.op == "row":
            return ("row", e.row)
        return (e.op,) + tuple(key(a) for a in e.args)

    def count(e: Expr):
        k = key(e)
        refcounts[k] = refcounts.get(k, 0) + 1
        if refcounts[k] == 1 and e.op != "row":
            for a in e.args:
                count(a)

    count(expr)

    def alloc_temp() -> str:
        nonlocal n_temps
        if free_temps:
            return free_temps.pop()
        name = f"{temp_prefix}{n_temps}"
        n_temps += 1
        return name

    def release(row: str):
        if row.startswith(temp_prefix):
            free_temps.append(row)

    def emit(e: Expr, out: Optional[str]) -> str:
        k = key(e)
        if e.op == "row":
            if out is not None and out != e.row:
                commands.extend(copy_program(e.row, out).commands)
                return out
            return e.row
        if k in memo and out is None:
            return memo[k]
        src_rows = [emit(a, None) for a in e.args]
        # rows that die after this op can host the result in-place: every
        # Fig. 8 program stages its sources into designated rows before the
        # final AAP writes the destination, so dst == src is safe.
        dying = [r for a, r in zip(e.args, src_rows)
                 if refcounts[key(a)] == 1 and r.startswith(temp_prefix)]
        if out is not None:
            dst_row = out
        elif dying:
            dst_row = dying[0]
        else:
            dst_row = alloc_temp()
        commands.extend(op_program(e.op, src_rows, dst_row).commands)
        for a, r in zip(e.args, src_rows):
            refcounts[key(a)] -= 1
            if refcounts[key(a)] == 0 and r != dst_row:
                release(r)
        if out is None:
            memo[k] = dst_row
        return dst_row

    emit(expr, dst)
    return CompileResult(Program(commands, f"{dst} = <expr>"), n_temps)
