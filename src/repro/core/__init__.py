"""Buddy-RAM core: the paper's contribution as a composable JAX module."""
from repro.core.bitplane import (BitVector, pack_bits, unpack_bits, n_words,
                                 WORD_BITS, ROW_BITS, ROW_WORDS)
from repro.core.commands import AAP, AP, Program
from repro.core.compiler import (Expr, maj, compile_expr, compile_expr_fused,
                                 fuse_expr, optimize_program, op_program,
                                 and_program, or_program, not_program,
                                 nand_program, nor_program, xor_program,
                                 xnor_program, maj3_program, andnot_program,
                                 copy_program)
from repro.core.engine import Subarray, execute
from repro.core.bankgroup import (BankGroup, BankSchedule, execute_banked,
                                  pipeline_latency_ns, banked_throughput_gbps,
                                  shard_words, unshard_words)
from repro.core.timing import (DDR3_1600, DramTiming, program_latency_ns,
                               buddy_throughput_gbps, baseline_throughput_gbps,
                               throughput_table, SKYLAKE, GTX745)
from repro.core.energy import (EnergyModel, DEFAULT_ENERGY, program_energy_nj,
                               buddy_energy_nj_per_kb, ddr3_energy_nj_per_kb,
                               energy_table)
from repro.core.isa import BuddyDevice, BopResult
from repro.core.errors import (TRAErrorModel, ReliabilityConfig, error_planes,
                               single_fault_planes, execute_injected,
                               execute_voted, execute_ecc, vote_outputs)
