"""DRAM command IR: ACTIVATE / PRECHARGE micro-ops and the AAP/AP primitives.

The paper's controller expresses every bitwise operation as a sequence of
AAP(addr1, addr2) = ACTIVATE addr1; ACTIVATE addr2; PRECHARGE
AP(addr)         = ACTIVATE addr; PRECHARGE
(§5.2). No new DRAM commands are introduced — only reserved addresses.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class Activate:
    addr: str


@dataclasses.dataclass(frozen=True)
class Precharge:
    pass


MicroOp = Union[Activate, Precharge]


@dataclasses.dataclass(frozen=True)
class AAP:
    """ACTIVATE-ACTIVATE-PRECHARGE. Copies result of sensing addr1 into the
    row(s) mapped to addr2 (n-wordline targets capture the negation)."""

    addr1: str
    addr2: str

    def micro_ops(self) -> Tuple[MicroOp, ...]:
        return (Activate(self.addr1), Activate(self.addr2), Precharge())


@dataclasses.dataclass(frozen=True)
class AP:
    """ACTIVATE-PRECHARGE (used when the TRA result only needs to land in the
    rows the address itself raises, e.g. AP(B14))."""

    addr: str

    def micro_ops(self) -> Tuple[MicroOp, ...]:
        return (Activate(self.addr), Precharge())


Command = Union[AAP, AP]


@dataclasses.dataclass
class Program:
    """A straight-line sequence of AAP/AP commands implementing one bulk
    bitwise operation on row-granularity operands."""

    commands: List[Command]
    comment: str = ""

    def micro_ops(self) -> Iterator[MicroOp]:
        for c in self.commands:
            yield from c.micro_ops()

    @property
    def n_aap(self) -> int:
        return sum(isinstance(c, AAP) for c in self.commands)

    @property
    def n_ap(self) -> int:
        return sum(isinstance(c, AP) for c in self.commands)

    def activates(self) -> List[str]:
        return [m.addr for m in self.micro_ops() if isinstance(m, Activate)]

    def __add__(self, other: "Program") -> "Program":
        return Program(self.commands + other.commands,
                       f"{self.comment};{other.comment}")

    def __repr__(self) -> str:
        lines = [f"Program({self.comment!r})"]
        for c in self.commands:
            if isinstance(c, AAP):
                lines.append(f"  AAP({c.addr1}, {c.addr2})")
            else:
                lines.append(f"  AP({c.addr})")
        return "\n".join(lines)


def concat(programs: Sequence[Program], comment: str = "") -> Program:
    cmds: List[Command] = []
    for p in programs:
        cmds.extend(p.commands)
    return Program(cmds, comment)
