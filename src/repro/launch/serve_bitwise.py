"""Bulk-bitwise query-serving driver: replay a multi-tenant stream.

    PYTHONPATH=src python -m repro.launch.serve_bitwise \
        --tenants 4 --weeks 3 --queries 96 --banks 8

Builds the synthetic §8 workload catalog (`repro.service.workload`), serves
the query stream through the batching scheduler, and prints per-batch QPS,
p50/p99 modeled latency, plan-cache hit rate, and energy — the interactive
serving loop the ROADMAP's "heavy traffic" north star grows from.

``--explain`` prints the cost-based optimizer's plan report for the first
batch: per-plan AAP counts (optimized vs as-written), chosen backend, and
the cross-query shared subexpression planes.

Telemetry (`repro.obs`): ``--telemetry`` turns on full query-lifecycle
tracing and prints the metrics dashboard after the stream; ``--trace-out
trace.json`` writes the Chrome trace-event timeline (open in Perfetto /
`chrome://tracing`), ``--prom-out metrics.prom`` the Prometheus snapshot.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from repro.obs import Telemetry
from repro.service import (WorkloadSpec, build_service, query_stream,
                           results_bit_identical, run_queries_unbatched)


def _dashboard(svc) -> str:
    """Human-readable telemetry summary from the unified stat surface."""
    s = svc.stats()
    lines = [
        "-- telemetry ----------------------------------------------",
        f"queries served      {int(s['queries_served'])} "
        f"in {int(s.get('batches', 0))} batches",
        f"plan cache          {int(s['plan_cache_hits'])} hits / "
        f"{int(s['plan_cache_misses'])} misses "
        f"(rate {s['plan_cache_hit_rate']:.2f}, "
        f"{int(s['plans_cached'])} plans)",
        f"modeled latency     p50 {s.get('modeled_latency_p50_ns', 0.0) / 1e3:.1f}us  "
        f"p99 {s.get('modeled_latency_p99_ns', 0.0) / 1e3:.1f}us",
        f"modeled totals      {s['total_modeled_ns'] / 1e6:.3f} ms, "
        f"{s['total_energy_nj'] / 1e3:.1f} uJ",
        f"reliability         {int(s.get('reliability_replicas', 0))} replicas, "
        f"{int(s.get('ecc_tiebreaks', 0))} tiebreaks, "
        f"{int(s.get('tra_corrected_bits', 0))} corrected bits, "
        f"{int(s['parity_checks'])} parity checks",
        f"fault tolerance     {int(s['failures'])} failures, "
        f"{int(s['replays'])} replays, {int(s['stragglers'])} stragglers, "
        f"{int(s.get('chip_rescales', 0))} rescales",
    ]
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--weeks", type=int, default=3)
    ap.add_argument("--domain", type=int, default=1 << 12,
                    help="bit domain (users / column length)")
    ap.add_argument("--queries", type=int, default=96)
    ap.add_argument("--banks", type=int, default=8)
    ap.add_argument("--batches", type=int, default=3,
                    help="replay the stream this many times (cache warm-up "
                         "shows up as rising hit rate)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="also run the sequential unbatched reference and "
                         "assert bit-identical results")
    ap.add_argument("--explain", action="store_true",
                    help="print the optimizer's per-plan cost breakdown "
                         "(backend choice, AAPs vs unoptimized, shared "
                         "CSE planes) for the first batch")
    ap.add_argument("--telemetry", action="store_true",
                    help="full tracing + metrics dashboard")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the Chrome trace-event JSON here "
                         "(implies --telemetry)")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the Prometheus metrics snapshot here")
    args = ap.parse_args(argv)

    trace_on = args.telemetry or args.trace_out is not None
    tel = Telemetry(trace=trace_on) if trace_on else None

    spec = WorkloadSpec(n_tenants=args.tenants, n_weeks=args.weeks,
                        domain_bits=args.domain, n_queries=args.queries,
                        seed=args.seed)
    svc = build_service(spec, n_banks=args.banks, telemetry=tel)
    print(f"catalog: {len(svc.catalog)} vectors, "
          f"domain={svc.catalog.n_bits} bits, banks={args.banks}")

    for batch in range(args.batches):
        queries = query_stream(
            dataclasses.replace(spec, seed=spec.seed + batch), svc)
        if args.explain and batch == 0:
            print(svc.explain(queries))
        t0 = time.perf_counter()
        rep = svc.query_batch(queries)
        wall = time.perf_counter() - t0
        stats = svc.stats()
        print(f"batch {batch}: {len(queries)} queries in "
              f"{rep.makespan_ns / 1e6:.3f} modeled ms "
              f"(wall {wall * 1e3:.0f} ms) "
              f"qps={rep.qps:.0f} "
              f"p50={rep.latency_percentile_ns(50) / 1e3:.1f}us "
              f"p99={rep.latency_percentile_ns(99) / 1e3:.1f}us "
              f"hit_rate={stats['plan_cache_hit_rate']:.2f} "
              f"plans={int(stats['plans_cached'])} "
              f"energy={stats['total_energy_nj'] / 1e3:.1f}uJ")
        if args.verify:
            ref = run_queries_unbatched(svc.catalog, queries)
            ok = results_bit_identical(rep.results, ref.results)
            print(f"  verify: bit-identical={ok} "
                  f"serial_ms={ref.makespan_ns / 1e6:.3f} "
                  f"speedup={ref.makespan_ns / rep.makespan_ns:.1f}x")
            if not ok:
                return 1

    if trace_on:
        print(_dashboard(svc))
    if args.trace_out:
        path = svc.export_chrome_trace(args.trace_out)
        n_ev = len(svc.telemetry.tracer.events)
        print(f"chrome trace: {n_ev} events -> {path}")
    if args.prom_out:
        with open(args.prom_out, "w") as f:
            f.write(svc.prometheus())
        print(f"prometheus snapshot -> {args.prom_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
