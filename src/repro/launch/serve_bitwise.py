"""Bulk-bitwise query-serving driver: replay a multi-tenant stream.

    PYTHONPATH=src python -m repro.launch.serve_bitwise \
        --tenants 4 --weeks 3 --queries 96 --banks 8

Builds the synthetic §8 workload catalog (`repro.service.workload`), serves
the query stream through the batching scheduler, and prints per-batch QPS,
p50/p99 modeled latency, plan-cache hit rate, and energy — the interactive
serving loop the ROADMAP's "heavy traffic" north star grows from.

``--explain`` prints the cost-based optimizer's plan report for the first
batch: per-plan AAP counts (optimized vs as-written), chosen backend, and
the cross-query shared subexpression planes.

``--serve-loop`` switches from closed-loop batch replay to the
continuous-serving runtime: a seeded open-loop Poisson trace
(`poisson_arrivals`) replayed through `ServingLoop` with slot-packing
ticks, double-buffered plan/execute pipelining, and SLO admission
control (``--rate`` offered QPS, ``--slo-p99-us`` target,
``--slo-policy shed|defer|none``). The dashboard streams per-tick
occupancy / queue depth / shed lines while the trace runs.

Telemetry (`repro.obs`): ``--telemetry`` turns on full query-lifecycle
tracing and prints the metrics dashboard after the stream; ``--trace-out
trace.json`` writes the Chrome trace-event timeline (open in Perfetto /
`chrome://tracing`), ``--prom-out metrics.prom`` the Prometheus snapshot.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from repro.obs import Telemetry
from repro.service import (ServiceConfig, SloConfig, WorkloadSpec,
                           build_service, poisson_arrivals, query_stream,
                           results_bit_identical, run_queries_unbatched)


def _dashboard(svc) -> str:
    """Human-readable telemetry summary from the unified stat surface."""
    s = svc.stats()
    lines = [
        "-- telemetry ----------------------------------------------",
        f"queries served      {int(s['queries_served'])} "
        f"in {int(s.get('batches', 0))} batches",
        f"plan cache          {int(s['plan_cache_hits'])} hits / "
        f"{int(s['plan_cache_misses'])} misses "
        f"(rate {s['plan_cache_hit_rate']:.2f}, "
        f"{int(s['plans_cached'])} plans)",
        f"modeled latency     p50 {s.get('modeled_latency_p50_ns', 0.0) / 1e3:.1f}us  "
        f"p99 {s.get('modeled_latency_p99_ns', 0.0) / 1e3:.1f}us",
        f"modeled totals      {s['total_modeled_ns'] / 1e6:.3f} ms, "
        f"{s['total_energy_nj'] / 1e3:.1f} uJ",
        f"reliability         {int(s.get('reliability_replicas', 0))} replicas, "
        f"{int(s.get('ecc_tiebreaks', 0))} tiebreaks, "
        f"{int(s.get('tra_corrected_bits', 0))} corrected bits, "
        f"{int(s['parity_checks'])} parity checks",
        f"fault tolerance     {int(s['failures'])} failures, "
        f"{int(s['replays'])} replays, {int(s['stragglers'])} stragglers, "
        f"{int(s.get('chip_rescales', 0))} rescales",
    ]
    return "\n".join(lines)


def _serve_dashboard(rep) -> str:
    """Post-run summary of a ServingLoop trace replay."""
    lines = [
        "-- serving loop -------------------------------------------",
        f"served {len(rep.served)} / shed {len(rep.shed)} "
        f"(shed_frac {rep.shed_frac:.2f}, "
        f"deferred {rep.deferred_total})",
        f"ticks {len(rep.ticks)}  "
        f"occupancy mean {rep.occupancy_mean:.2f}  "
        f"capacity {rep.capacity}  "
        f"pipelined {rep.pipelined}",
        f"sustained {rep.sustained_qps:.0f} modeled qps "
        f"({rep.wall_qps:.0f} wall qps)",
        f"sojourn p50 {rep.sojourn_percentile_ns(50) / 1e3:.1f}us  "
        f"p99 {rep.sojourn_percentile_ns(99) / 1e3:.1f}us",
    ]
    if rep.slo is not None:
        p99 = rep.sojourn_percentile_ns(99)
        ok = "OK" if p99 <= rep.slo.p99_ns else "BREACH"
        lines.append(f"slo p99 target {rep.slo.p99_ns / 1e3:.1f}us "
                     f"policy={rep.slo.policy} -> {ok}")
    return "\n".join(lines)


def _run_serve_loop(args, svc, spec) -> int:
    slo = None
    if args.slo_policy != "off":
        slo = SloConfig(p99_ns=args.slo_p99_us * 1e3,
                        policy=args.slo_policy)
    arrivals = poisson_arrivals(spec, svc, rate_qps=args.rate,
                                n_arrivals=args.queries)
    print(f"open-loop trace: {len(arrivals)} arrivals at "
          f"{args.rate:.0f} offered qps "
          f"({len({a.query.tenant for a in arrivals})} tenants)")

    def tick_line(t):
        print(f"  tick {t.tick:3d}: {t.n_queries:3d} queries "
              f"in {t.n_groups} groups  "
              f"occ {t.occupancy:.2f}  depth {t.queue_depth:3d}  "
              f"makespan {t.makespan_ns / 1e3:.1f}us")

    loop = svc.serve_loop(depth=args.depth, slo=slo,
                          on_tick=tick_line if args.tick_log else None)
    rep = loop.run_trace(arrivals)
    print(_serve_dashboard(rep))
    if args.verify:
        served = [r for r in rep.records if r.status == "served"]
        ref = run_queries_unbatched(svc.catalog,
                                    [arrivals[r.index].query
                                     for r in served])
        ok = results_bit_identical([r.result for r in served], ref.results)
        print(f"  verify: bit-identical={ok}")
        if not ok:
            return 1
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--weeks", type=int, default=3)
    ap.add_argument("--domain", type=int, default=1 << 12,
                    help="bit domain (users / column length)")
    ap.add_argument("--queries", type=int, default=96)
    ap.add_argument("--banks", type=int, default=8)
    ap.add_argument("--batches", type=int, default=3,
                    help="replay the stream this many times (cache warm-up "
                         "shows up as rising hit rate)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="also run the sequential unbatched reference and "
                         "assert bit-identical results")
    ap.add_argument("--explain", action="store_true",
                    help="print the optimizer's per-plan cost breakdown "
                         "(backend choice, AAPs vs unoptimized, shared "
                         "CSE planes) for the first batch")
    ap.add_argument("--telemetry", action="store_true",
                    help="full tracing + metrics dashboard")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the Chrome trace-event JSON here "
                         "(implies --telemetry)")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the Prometheus metrics snapshot here")
    ap.add_argument("--serve-loop", action="store_true",
                    help="continuous-serving mode: replay a seeded "
                         "open-loop Poisson trace through ServingLoop "
                         "(slot-packing ticks, pipelined dispatch, SLO "
                         "admission control)")
    ap.add_argument("--rate", type=float, default=200_000.0,
                    help="serve-loop offered load, modeled queries/sec")
    ap.add_argument("--depth", type=int, default=4,
                    help="serve-loop queue depth per slot "
                         "(tick capacity = slots * depth)")
    ap.add_argument("--slo-p99-us", type=float, default=5e3,
                    help="serve-loop p99 sojourn target, microseconds")
    ap.add_argument("--slo-policy", default="shed",
                    choices=["shed", "defer", "none", "off"],
                    help="admission policy on projected SLO breach "
                         "('off' disables the SLO entirely)")
    ap.add_argument("--tick-log", action="store_true",
                    help="stream a dashboard line per serving tick")
    args = ap.parse_args(argv)

    trace_on = args.telemetry or args.trace_out is not None
    tel = Telemetry(trace=trace_on) if trace_on else None

    spec = WorkloadSpec(n_tenants=args.tenants, n_weeks=args.weeks,
                        domain_bits=args.domain, n_queries=args.queries,
                        seed=args.seed)
    svc = build_service(spec, n_banks=args.banks, telemetry=tel)
    print(f"catalog: {len(svc.catalog)} vectors, "
          f"domain={svc.catalog.n_bits} bits, banks={args.banks}")

    if args.serve_loop:
        rc = _run_serve_loop(args, svc, spec)
        if trace_on:
            print(_dashboard(svc))
        if args.trace_out:
            path = svc.export_chrome_trace(args.trace_out)
            n_ev = len(svc.telemetry.tracer.events)
            print(f"chrome trace: {n_ev} events -> {path}")
        if args.prom_out:
            with open(args.prom_out, "w") as f:
                f.write(svc.prometheus())
            print(f"prometheus snapshot -> {args.prom_out}")
        return rc

    for batch in range(args.batches):
        queries = query_stream(
            dataclasses.replace(spec, seed=spec.seed + batch), svc)
        if args.explain and batch == 0:
            print(svc.explain(queries))
        t0 = time.perf_counter()
        rep = svc.query_batch(queries)
        wall = time.perf_counter() - t0
        stats = svc.stats()
        print(f"batch {batch}: {len(queries)} queries in "
              f"{rep.makespan_ns / 1e6:.3f} modeled ms "
              f"(wall {wall * 1e3:.0f} ms) "
              f"qps={rep.qps:.0f} "
              f"p50={rep.latency_percentile_ns(50) / 1e3:.1f}us "
              f"p99={rep.latency_percentile_ns(99) / 1e3:.1f}us "
              f"hit_rate={stats['plan_cache_hit_rate']:.2f} "
              f"plans={int(stats['plans_cached'])} "
              f"energy={stats['total_energy_nj'] / 1e3:.1f}uJ")
        if args.verify:
            ref = run_queries_unbatched(svc.catalog, queries)
            ok = results_bit_identical(rep.results, ref.results)
            print(f"  verify: bit-identical={ok} "
                  f"serial_ms={ref.makespan_ns / 1e6:.3f} "
                  f"speedup={ref.makespan_ns / rep.makespan_ns:.1f}x")
            if not ok:
                return 1

    if trace_on:
        print(_dashboard(svc))
    if args.trace_out:
        path = svc.export_chrome_trace(args.trace_out)
        n_ev = len(svc.telemetry.tracer.events)
        print(f"chrome trace: {n_ev} events -> {path}")
    if args.prom_out:
        with open(args.prom_out, "w") as f:
            f.write(svc.prometheus())
        print(f"prometheus snapshot -> {args.prom_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
