"""Cell construction: one (architecture x input-shape x mesh) dry-run cell =
a jitted step function + ShapeDtypeStruct arguments + shardings.

Used by dryrun.py (lower/compile/memory/cost), roofline.py (term extraction)
and the perf pass (plans with overrides). No device allocation happens here —
everything is abstract until `.lower().compile()`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ShapeConfig, get_config
from repro.dist.sharding import (DECODE_SP_RULES, DEFAULT_RULES, DP_RULES,
                                 SP_RULES, axis_rules, resolve_spec,
                                 tree_shardings)
from repro.launch.plans import CellPlan, plan_for
from repro.models import registry
from repro.optim.optimizers import get_optimizer
from repro.optim.schedules import warmup_cosine
from repro.train.step import make_train_step


def rules_named(name: str):
    return {"default": DEFAULT_RULES, "sp": SP_RULES,
            "decode_sp": DECODE_SP_RULES, "dp": DP_RULES}.get(
        name, DEFAULT_RULES)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    plan: CellPlan
    fn: Callable                  # the function to lower
    args: Tuple                   # ShapeDtypeStruct args
    in_shardings: Tuple
    out_shardings: Any            # or None (auto)
    mesh: Mesh

    def lower(self):
        from repro.models.layers import attention_backend, attention_remat
        from repro.models.moe import moe_constraints
        with self.mesh, axis_rules(self.mesh, rules_named(self.plan.rules)), \
                attention_remat(self.plan.attn_remat), \
                attention_backend(self.plan.attn_kernel), \
                moe_constraints(self.plan.moe_constrain):
            jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                             out_shardings=self.out_shardings)
            return jitted.lower(*self.args)


def _batch_shardings(cfg, shape, mesh, rules):
    specs = registry.batch_logical_specs(cfg, shape)
    abstract = registry.input_specs(cfg, shape)
    return tree_shardings(abstract, specs, mesh, rules), abstract


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               overrides: Optional[dict] = None,
               reduce_config: bool = False,
               shape_override: Optional[ShapeConfig] = None) -> Cell:
    cfg = get_config(arch)
    if reduce_config:
        from repro.configs.base import reduced
        cfg = reduced(cfg)
    shape = shape_override or SHAPES[shape_name]
    plan = plan_for(cfg, shape, overrides)
    # clamp accumulation to a divisor of the (possibly overridden) batch
    accum = plan.grad_accum
    while accum > 1 and shape.global_batch % accum:
        accum //= 2
    if accum != plan.grad_accum:
        plan = dataclasses.replace(plan, grad_accum=accum)
    rules = rules_named(plan.rules)
    bundle = registry.build(cfg, remat=plan.remat)
    param_shapes, param_specs = bundle.abstract()
    with axis_rules(mesh, rules):
        p_shard = tree_shardings(param_shapes, param_specs, mesh, rules)

        if shape.kind == "train":
            if plan.compressed_dp:
                # majority-vote 1-bit signSGD inside shard_map over the DP
                # axes — the paper's TRA as the gradient collective.
                from repro.train.step import make_train_step_compressed
                dp_axes = tuple(a for a in ("pod", "data")
                                if a in mesh.axis_names)
                # use_kernel=False: interpret-mode pallas does not partition
                # under GSPMD (dry-run only; the pack/majority kernels are
                # exercised by tests/test_kernels.py on their own)
                opt = get_optimizer("signum",
                                    warmup_cosine(3e-4, 100, 10_000),
                                    axis_name=(dp_axes if len(dp_axes) > 1
                                               else dp_axes[0]),
                                    use_kernel=False)
                opt_shapes = jax.eval_shape(opt.init, param_shapes)
                o_shard = _opt_shardings(opt_shapes, param_shapes,
                                         param_specs, mesh, rules)
                step_fn = make_train_step_compressed(
                    bundle, opt, mesh, dp_axes=dp_axes,
                    grad_accum=plan.grad_accum)
            else:
                opt = get_optimizer(plan.optimizer,
                                    warmup_cosine(3e-4, 100, 10_000))
                opt_shapes = jax.eval_shape(opt.init, param_shapes)
                o_shard = _opt_shardings(opt_shapes, param_shapes,
                                         param_specs, mesh, rules)
                step_fn = make_train_step(bundle, opt,
                                          grad_accum=plan.grad_accum)
            b_shard, b_abs = _batch_shardings(cfg, shape, mesh, rules)
            args = (param_shapes, opt_shapes,
                    jax.ShapeDtypeStruct((), jnp.int32), b_abs)
            in_sh = (p_shard, o_shard, NamedSharding(mesh, P()), b_shard)
            out_sh = (p_shard, o_shard, None)
            return Cell(arch, shape, plan, step_fn, args, in_sh, out_sh, mesh)

        if shape.kind == "prefill":
            b_shard, b_abs = _batch_shardings(cfg, shape, mesh, rules)

            def prefill_fn(params, batch):
                return bundle.prefill(params, batch)

            args = (param_shapes, b_abs)
            in_sh = (p_shard, b_shard)
            return Cell(arch, shape, plan, prefill_fn, args, in_sh, None,
                        mesh)

        # decode: serve_step(params, token, cache, pos)
        b_shard, b_abs = _batch_shardings(cfg, shape, mesh, rules)

        def serve_step(params, token, cache, pos):
            return bundle.decode_step(params, token, cache, pos)

        args = (param_shapes, b_abs["token"], b_abs["cache"], b_abs["pos"])
        in_sh = (p_shard, b_shard["token"], b_shard["cache"],
                 NamedSharding(mesh, P()))
        # cache out must match cache in (steady-state decode loop)
        out_sh = (None, b_shard["cache"])
        return Cell(arch, shape, plan, serve_step, args, in_sh, out_sh, mesh)


def _opt_shardings(opt_shapes, param_shapes, param_specs, mesh, rules):
    """Optimizer state mirrors params (adamw m/v, signum mu/err) or carries
    factored stats (adafactor r/c) — derive shardings leaf-by-leaf: any leaf
    whose shape matches the param's gets the param spec; reduced-rank
    (factored) leaves inherit the matching prefix/suffix of the spec."""
    flat_p, _ = jax.tree_util.tree_flatten_with_path(param_shapes)
    spec_by_shape: Dict[Tuple, Any] = {}
    flat_s = jax.tree.leaves(param_specs,
                             is_leaf=lambda x: isinstance(x, tuple))
    for (path, leaf), spec in zip(flat_p, flat_s):
        spec_by_shape.setdefault(tuple(leaf.shape), spec)

    def one(leaf):
        names = spec_by_shape.get(tuple(leaf.shape))
        if names is None:
            # factored stats: try matching a prefix or suffix of some param
            for shp, spec in spec_by_shape.items():
                if tuple(leaf.shape) == shp[:-1]:
                    names = spec[:-1]
                    break
                if tuple(leaf.shape) == shp[:-2] + shp[-1:]:
                    names = spec[:-2] + spec[-1:]
                    break
        if names is None:
            names = (None,) * leaf.ndim
        return NamedSharding(mesh,
                             resolve_spec(leaf.shape, names, mesh, rules))

    return jax.tree.map(one, opt_shapes)
