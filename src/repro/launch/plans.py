"""Per-(arch x shape) execution plans: optimizer, microbatching, remat.

grad_accum is sized so the per-chip activation working set stays in the
single-digit-GB range on a 16 GB v5e: saved block inputs per chip are
roughly tokens/accum x d_model x 2B x n_layers / data_shards. The largest
models use Adafactor (factored second moments) because full Adam state for
1T params cannot fit a 256-chip pod (see DESIGN.md §memory budget).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class CellPlan:
    arch: str
    shape: str
    optimizer: str = "adamw"      # adamw | adafactor | signum
    grad_accum: int = 1
    remat: str = "block"          # block | dots | full
    rules: str = "default"        # default | sp (sequence-parallel) | dp
    attn_remat: bool = True       # flash-style q-row checkpoint (layers.py)
    attn_kernel: str = "chunked"  # chunked | flash (Pallas, perf pass)
    compressed_dp: bool = False   # 1-bit majority-vote gradient exchange
    moe_constrain: bool = False   # force expert sharding constraints
    notes: str = ""


_OPT: Dict[str, str] = {
    "kimi_k2_1t_a32b": "adafactor",
    "llama4_maverick_400b_a17b": "adafactor",
}

_ACCUM: Dict[str, int] = {
    # train_4k (1.05M global tokens/step): keep microbatch activations and
    # MoE dispatch buffers per chip in the low-GB range.
    "zamba2_2p7b": 2,
    "seamless_m4t_medium": 1,
    "qwen3_8b": 4,
    "deepseek_67b": 8,
    "qwen1p5_110b": 8,
    "qwen3_0p6b": 1,
    "kimi_k2_1t_a32b": 16,
    "llama4_maverick_400b_a17b": 8,
    "llama_3p2_vision_90b": 8,
    "mamba2_1p3b": 2,
}


def plan_for(cfg: ModelConfig, shape: ShapeConfig,
             overrides: Optional[dict] = None) -> CellPlan:
    arch = cfg.name.replace("-", "_").replace(".", "p")
    kw = dict(
        arch=arch, shape=shape.name,
        optimizer=_OPT.get(arch, "adamw"),
        grad_accum=_ACCUM.get(arch, 1) if shape.kind == "train" else 1,
        remat="block",
        rules="default",
        attn_remat=shape.kind == "train",
    )
    kw.update(overrides or {})
    return CellPlan(**kw)
