"""Serving driver: batched prefill + decode with the KV/SSM cache machinery.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0p6b \
        --batch 4 --prompt-len 64 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.models import build
from repro.serve.step import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0p6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.n_frontend_tokens,
                  cfg.frontend_dim or cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.n_frontend_tokens,
                  cfg.frontend_dim or cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    toks = generate(bundle, params, batch, args.max_new,
                    temperature=args.temperature, key=key)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print("first sequence:", toks[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
