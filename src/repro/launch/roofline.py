"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch x shape x mesh) cell, all in seconds:

    compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective = collective_bytes / (chips x 50e9 B/s ICI link)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). collective_bytes is
parsed from the post-SPMD optimized HLO text: for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op we sum the
operand sizes (a name->shape table is built from the instruction defs, so
operand sizes are exact, not guessed from the output shape).

MODEL_FLOPS is the analytic useful-work number (6·N·D train, 2·N·D forward,
per the assignment: N_active for MoE); its ratio against HLO_FLOPs exposes
remat recompute and routing/dispatch waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig
# TPU v5e per-chip constants live in `repro.hw` (shared with the measured
# bandwidth benchmark, benchmarks/vm_stream.py); re-exported here for
# existing importers of roofline.PEAK_FLOPS et al.
from repro.hw import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: F401

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# "%name = <shape-or-tuple> opcode(...)" — instruction definition
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}:\s]+?)\s+"
    r"([\w\-]+)\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind operand bytes + op counts from optimized HLO."""
    shapes: Dict[str, str] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m:
            shapes[m.group(1)] = m.group(2)
    stats = {k: {"count": 0, "operand_bytes": 0, "output_bytes": 0}
             for k in _COLLECTIVES}
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, out_shape, op = m.groups()
        kind = next((k for k in _COLLECTIVES
                     if op == k or op.startswith(k + "-")), None)
        if kind is None:
            continue
        # operand list: %arg names inside the call parens
        call = ln[ln.index(op + "(") + len(op) + 1:]
        depth = 1
        args = ""
        for ch in call:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        op_bytes = 0
        for ref in re.findall(r"%?([\w.\-]+)", args):
            if ref in shapes:
                op_bytes += _shape_bytes(shapes[ref])
        stats[kind]["count"] += 1
        stats[kind]["operand_bytes"] += op_bytes
        stats[kind]["output_bytes"] += _shape_bytes(out_shape)
    return stats


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (train) / 2·N·D (fwd) with N = active params, D = tokens.
    For enc-dec the encoder weights see only the frame tokens, so N·D splits
    into N_dec·T_text + N_enc·T_frames (otherwise seamless would report a
    'useful ratio' > 1)."""
    n = cfg.param_count(active_only=(cfg.family == "moe"))
    mult = 6.0 if shape.kind == "train" else 2.0
    if shape.kind == "decode":
        return mult * n * shape.global_batch
    t_text = shape.global_batch * shape.seq_len
    if cfg.family == "encdec":
        D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        mlp = (3 if cfg.mlp_kind == "swiglu" else 2) * D * cfg.d_ff
        n_enc = cfg.n_enc_layers * (attn + mlp)
        t_frames = shape.global_batch * cfg.n_frontend_tokens
        return mult * ((n - n_enc) * t_text + n_enc * t_frames)
    return mult * n * t_text


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, Dict[str, float]]
    model_flops_: float
    bytes_per_device: Optional[float] = None
    dot_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_memory_floor(self) -> float:
        """Matmul-attributed traffic only — fusion-granularity independent
        (XLA:CPU fuses less than TPU; the true TPU memory term lies between
        t_memory_floor and t_memory)."""
        return self.dot_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_ / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term bound that is useful model compute:
        (MODEL_FLOPS / chips / peak) / max(term). 1.0 = the step takes
        exactly as long as the useful flops at peak — the roofline."""
        t_use = self.model_flops_ / (self.chips * PEAK_FLOPS)
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return t_use / t_step if t_step else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": self.collective_by_kind,
            "model_flops": self.model_flops_,
            "bytes_per_device": self.bytes_per_device,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_memory_floor_s": self.t_memory_floor,
            "dot_bytes": self.dot_bytes,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, cfg: ModelConfig, shape: ShapeConfig, mesh_name: str,
            chips: int, arch: str) -> Roofline:
    """NOTE: XLA's cost_analysis() counts while (scan) bodies once — useless
    for scan-over-layers models — so FLOPs/bytes/collective-bytes come from
    the trip-count-aware walker in launch/hlocost.py (validated against XLA
    on unrolled modules in tests/test_hlocost.py). Costs are per-partition;
    the roofline terms below are therefore per-chip by construction, and the
    assignment's "/ chips" is already applied by SPMD partitioning."""
    from repro.launch.hlocost import analyze_text
    text = compiled.as_text()
    cost = analyze_text(text)
    # per-chip numbers (post-SPMD module) -> keep terms per chip
    flops = cost.flops * chips          # global, for reporting
    byts = cost.bytes * chips
    dot_bytes = cost.dot_bytes * chips
    coll_bytes = cost.collective_bytes * chips
    # per-kind op counts (trip-count multiplied, per chip)
    coll = {k: {"count": v} for k, v in cost.collective_ops.items()}
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = (getattr(ma, "argument_size_in_bytes", 0)
                   + getattr(ma, "output_size_in_bytes", 0)
                   + getattr(ma, "temp_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=byts,
                    collective_bytes=coll_bytes, collective_by_kind=coll,
                    model_flops_=model_flops(cfg, shape),
                    bytes_per_device=mem, dot_bytes=dot_bytes)
