"""Aggregate dry-run cell JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report results/ [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(results_dir: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(results_dir, "cell_*.json"))):
        out.append(json.load(open(f)))
    return out


def fmt_bytes(b):
    if b is None:
        return "—"
    return f"{b/2**30:.1f}GiB"


def markdown_table(rows: List[Dict], single_pod_only: bool = False) -> str:
    hdr = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) "
           "| dominant | useful | roofline | temp/chip | status |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        if single_pod_only and r.get("mesh") != "16x16":
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
                         f"| — | — | — | — | — | — | — | {r['status'][:40]} |")
            continue
        ma = r.get("memory_analysis") or {}
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']*1e3:.1f} | {r['t_memory_s']*1e3:.1f} "
            f"| {r['t_collective_s']*1e3:.1f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.4f} "
            f"| {fmt_bytes(ma.get('temp_size_in_bytes'))} | ok |")
    return "\n".join(lines)


def summarize(rows: List[Dict]) -> str:
    ok = [r for r in rows if r.get("status") == "ok"]
    bad = [r for r in rows if r.get("status") != "ok"]
    out = [f"{len(ok)}/{len(rows)} cells ok; {len(bad)} failed"]
    if ok:
        worst = sorted(ok, key=lambda r: r["roofline_fraction"])[:3]
        out.append("worst roofline fraction: " + ", ".join(
            f"{r['arch']}x{r['shape']}x{r['mesh']}"
            f"({r['roofline_fraction']:.4f})" for r in worst))
        coll = sorted(ok, key=lambda r: -r["t_collective_s"] /
                      max(r["t_compute_s"], 1e-12))[:3]
        out.append("most collective-bound (t_coll/t_comp): " + ", ".join(
            f"{r['arch']}x{r['shape']}x{r['mesh']}"
            f"({r['t_collective_s']/max(r['t_compute_s'],1e-12):.1f}x)"
            for r in coll))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("results_dir", nargs="?", default="results")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.results_dir)
    print(summarize(rows))
    print()
    print(markdown_table(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
