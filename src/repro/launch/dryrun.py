import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (architecture x input shape) cell
on the production meshes and extract memory/cost/roofline evidence.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first initialization, and the 512 placeholder host
devices exist only for the dry-run (smoke tests and benches see 1 device).
(`from __future__` is therefore deliberately absent here.)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b \
        --shape train_4k [--multi-pod] [--out results/]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--out results/]
"""
import argparse
import json
import time
import traceback


from repro.configs.base import SHAPES, cells, get_config
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             overrides: dict | None = None, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, overrides)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print("  memory_analysis:", mem)
    rl = analyze(compiled, get_config(arch), SHAPES[shape_name], mesh_name,
                 chips, arch)
    out = rl.to_dict()
    out.update({
        "lower_s": t_lower, "compile_s": t_compile,
        "plan": dataclass_dict(cell.plan),
        "memory_analysis": {
            k: getattr(mem, k, None)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
        } if mem is not None else None,
        "status": "ok",
    })
    if verbose:
        print("  cost:", f"flops={rl.hlo_flops:.3e}",
              f"bytes={rl.hlo_bytes:.3e}",
              f"coll_bytes={rl.collective_bytes:.3e}")
        print("  roofline:", f"compute={rl.t_compute*1e3:.2f}ms",
              f"memory={rl.t_memory*1e3:.2f}ms",
              f"mem_floor={rl.t_memory_floor*1e3:.2f}ms",
              f"collective={rl.t_collective*1e3:.2f}ms",
              f"dominant={rl.dominant}",
              f"useful={rl.useful_ratio:.3f}",
              f"roofline_frac={rl.roofline_fraction:.3f}")
    return out


def dataclass_dict(dc):
    import dataclasses
    return dataclasses.asdict(dc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--override", default="",
                    help="json dict of CellPlan overrides")
    args = ap.parse_args()
    overrides = json.loads(args.override) if args.override else None

    grid = (cells() if args.all else [(args.arch, args.shape)])
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    results = []
    for arch, shape in grid:
        for mp in meshes:
            try:
                results.append(run_cell(arch, shape, mp, overrides))
            except Exception as e:
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "status": f"error: {e}"})
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                tag = "all" if args.all else f"{args.arch}_{args.shape}"
                with open(os.path.join(args.out, f"dryrun_{tag}.json"),
                          "w") as f:
                    json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{ok}/{len(results)} cells compiled OK")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
