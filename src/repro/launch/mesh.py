"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import,
and smoke tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: Optional[int] = None, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    data = data or max(1, n // model)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
