"""Trip-count-aware HLO cost analysis.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` exposes) counts each
`while` body ONCE — for scan-over-layers models that under-reports FLOPs by
the layer count (validated empirically in tests/test_hlocost.py). This module
walks the optimized HLO text instead and:

  * multiplies while-loop body+condition costs by the trip count XLA records
    in `backend_config={"known_trip_count":{"n":...}}`,
  * counts dot FLOPs exactly (2 x out_elems x contracted dims, from
    `lhs_contracting_dims`),
  * approximates elementwise/reduce FLOPs as output/input element counts,
  * counts bytes as sum(operand bytes) + output bytes per materialized op,
    with fusion-internal instructions contributing flops but not bytes
    (same convention as HloCostAnalysis).

Costs are per-partition (the module is post-SPMD), matching the roofline's
per-chip peak constants.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_info(shape_str: str) -> Tuple[int, int]:
    """(total elements, total bytes) of a shape or tuple-shape string."""
    elems = byts = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    line: str


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"          # name
    r"((?:\([^)]*\))|(?:[\w\[\],{}]+))\s+"           # shape (maybe tuple)
    r"([\w\-]+)\(")                                   # opcode


def _parse_operands(line: str, opcode: str) -> List[str]:
    start = line.index(opcode + "(") + len(opcode) + 1
    depth = 1
    args = []
    cur = []
    for ch in line[start:]:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                break
        if ch == "," and depth == 1:
            args.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        args.append("".join(cur))
    out = []
    for a in args:
        a = a.strip()
        # operands may carry an inline type ("f32[64,32]{1,0} %Arg_0.1") in
        # some XLA dump versions — the %-prefixed token is the name
        m = re.search(r"%([\w.\-]+)\s*$", a) or re.match(r"%?([\w.\-]+)", a)
        if m:
            out.append(m.group(1))
    return out


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: float = 0.0
    collective_ops: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_counts: int = 0
    # matmul-attributed traffic (dot ops + fusions containing dots): a
    # fusion-granularity-independent FLOOR on HBM traffic. The raw `bytes`
    # reflects XLA:CPU fusion boundaries, which are finer than TPU's — the
    # true TPU memory term lies between bytes_dot and bytes.
    dot_bytes: float = 0.0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_ops.items():
            self.collective_ops[k] = self.collective_ops.get(k, 0) + v
        self.unknown_trip_counts += o.unknown_trip_counts
        self.dot_bytes += o.dot_bytes
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n, self.transcendentals * n,
                    self.collective_bytes * n,
                    {k: v * n for k, v in self.collective_ops.items()},
                    self.unknown_trip_counts, self.dot_bytes * n)


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    # ---- parsing ---------------------------------------------------------

    _COMP_HDR = re.compile(
        r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*\))?\s*->.*\{")

    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = self._COMP_HDR.match(line)
            if hdr and ("->" in line):
                cur = hdr.group(2)
                self.computations[cur] = []
                if hdr.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, shape, opcode = m.groups()
                ops = _parse_operands(line, opcode)
                self.computations[cur].append(
                    Instr(name, shape, opcode, ops, line))
        if self.entry is None and self.computations:
            self.entry = next(iter(self.computations))

    # ---- cost ------------------------------------------------------------

    def total(self) -> Cost:
        return self.comp_cost(self.entry, fused=False)

    def comp_cost(self, comp: str, fused: bool) -> Cost:
        key = (comp, fused)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()   # cycle guard
        shapes = {i.name: i.shape for i in self.computations.get(comp, [])}
        total = Cost()
        for ins in self.computations.get(comp, []):
            total += self._instr_cost(ins, shapes, fused)
        self._memo[key] = total
        return total

    def _operand_bytes(self, ins: Instr, shapes: Dict[str, str]) -> float:
        return float(sum(shape_info(shapes.get(o, ""))[1]
                         for o in ins.operands))

    def _instr_cost(self, ins: Instr, shapes: Dict[str, str], fused: bool
                    ) -> Cost:
        op = ins.opcode
        out_elems, out_bytes = shape_info(ins.shape)
        c = Cost()
        if op in _FREE_OPS:
            return c
        io_bytes = 0.0 if fused else \
            self._operand_bytes(ins, shapes) + out_bytes

        if op == "while":
            cond = _COND_RE.search(ins.line)
            body = _BODY_RE.search(ins.line)
            trip = _TRIP_RE.search(ins.line)
            n = int(trip.group(1)) if trip else 1
            if not trip:
                c.unknown_trip_counts += 1
            inner = Cost()
            if body:
                inner += self.comp_cost(body.group(1), fused=False)
            if cond:
                inner += self.comp_cost(cond.group(1), fused=False)
            c += inner.scaled(n)
            return c

        if op == "conditional":
            m = _BRANCHES_RE.search(ins.line)
            if m:
                branches = re.findall(r"%?([\w.\-]+)", m.group(1))
                # upper bound: sum of branches (XLA executes one; we take
                # max for flops to avoid double counting)
                costs = [self.comp_cost(b, fused=False) for b in branches]
                if costs:
                    best = max(costs, key=lambda x: x.flops)
                    c += best
            c.bytes += io_bytes
            return c

        if op == "fusion":
            m = _CALLS_RE.search(ins.line)
            inner = None
            if m:
                inner = self.comp_cost(m.group(1), fused=True)
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
                c.collective_bytes += inner.collective_bytes
            c.bytes += io_bytes
            if inner is not None and inner.dot_bytes > 0:
                # fusion wraps a dot: its io is matmul traffic
                c.dot_bytes += io_bytes
            return c

        if op in ("call", "custom-call", "async-start"):
            m = _TO_APPLY_RE.search(ins.line) or _CALLS_RE.search(ins.line)
            if m:
                c += self.comp_cost(m.group(1), fused=False)
            c.bytes += io_bytes
            return c

        # indexed data movement: reads/writes touch only the slice, not the
        # whole operand (XLA aliases dynamic-update-slice in place). Without
        # this, a decode step "reads" the entire KV cache once per layer and
        # interpret-mode Pallas grids read full operands once per grid step.
        if op in ("dynamic-slice", "gather"):
            c.bytes += 0.0 if fused else 2.0 * out_bytes
            return c
        if op in ("dynamic-update-slice", "scatter"):
            upd_idx = 1 if op == "dynamic-update-slice" else 2
            upd = ins.operands[upd_idx] if len(ins.operands) > upd_idx else \
                None
            upd_bytes = shape_info(shapes.get(upd, ""))[1] if upd else 0
            c.bytes += 0.0 if fused else 2.0 * upd_bytes
            return c

        kind = next((k for k in _COLLECTIVES
                     if op == k or op.startswith(k + "-")), None)
        if kind is not None:
            opb = self._operand_bytes(ins, shapes) or out_bytes
            c.collective_bytes += opb
            c.collective_ops[kind] = c.collective_ops.get(kind, 0) + 1
            c.bytes += io_bytes
            return c

        if op in ("dot", "dot-general"):
            m = _LHS_C_RE.search(ins.line)
            contract = 1
            if m and ins.operands:
                lhs_shape = shapes.get(ins.operands[0], "")
                dims = _SHAPE_RE.search(lhs_shape)
                if dims:
                    sizes = [int(d) for d in dims.group(2).split(",") if d]
                    for idx in m.group(1).split(","):
                        if idx and int(idx) < len(sizes):
                            contract *= sizes[int(idx)]
            c.flops += 2.0 * out_elems * contract
            c.bytes += io_bytes
            # unfused: io is matmul traffic; fused: 1-byte marker so the
            # enclosing fusion attributes its io instead (no double count)
            c.dot_bytes += io_bytes if io_bytes else 1.0
            return c

        if op == "convolution":
            # not used by our models; fall back to output-elems estimate
            c.flops += 2.0 * out_elems
            c.bytes += io_bytes
            return c

        if op in ("reduce", "reduce-window"):
            in_elems = sum(shape_info(shapes.get(o, ""))[0]
                           for o in ins.operands[:1])
            c.flops += float(in_elems)
            c.bytes += io_bytes
            return c

        if op in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                  "logistic", "sine", "cosine"):
            c.transcendentals += out_elems
            c.flops += out_elems
            c.bytes += io_bytes
            return c

        if op == "sort":
            import math
            c.flops += out_elems * max(1.0, math.log2(max(out_elems, 2)))
            c.bytes += io_bytes
            return c

        # generic elementwise / data movement
        c.flops += out_elems
        c.bytes += io_bytes
        return c


def analyze_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()
