"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_0p6b \
        --steps 200 --batch 8 --seq 128 [--reduced] [--opt adamw|signum] \
        [--ckpt-dir /tmp/ckpt] [--resume]

On this CPU container it drives the reduced configs (the full configs are
exercised by the dry-run); on a real TPU slice the same driver runs the full
configs — the mesh is built from whatever devices exist.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs.base import get_config, reduced
from repro.data import SyntheticLM
from repro.dist.fault_tolerance import ResilientRunner, StragglerMonitor
from repro.dist.sharding import axis_rules
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.optim.optimizers import get_optimizer
from repro.optim.schedules import warmup_cosine
from repro.train import make_train_step, make_train_step_compressed


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_0p6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--opt", default="adamw")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    bundle = build(cfg)
    mesh = make_host_mesh(model=args.model_parallel)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    print(f"arch={cfg.name} family={cfg.family} mesh={mesh_shape}")

    params = bundle.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    from repro.configs.base import ShapeConfig
    lr_fn = warmup_cosine(args.lr, max(10, args.steps // 20), args.steps)
    data = SyntheticLM.for_cell(
        cfg, ShapeConfig("cli", args.seq, args.batch, "train"))

    if args.opt == "signum" and len(jax.devices()) > 1:
        opt = get_optimizer("signum", lr_fn, axis_name="data")
        step_raw = make_train_step_compressed(
            bundle, opt, mesh, dp_axes=("data",), grad_accum=args.grad_accum)
    else:
        opt = get_optimizer(args.opt, lr_fn)
        step_raw = jax.jit(make_train_step(bundle, opt,
                                           grad_accum=args.grad_accum))
    opt_state = opt.init(params)

    def step_fn(state, step, batch):
        p, s = state
        with axis_rules(mesh):
            p, s, metrics = step_raw(p, s, jnp.int32(step), batch)
        return (p, s), metrics

    state = (params, opt_state)
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir, keep=3)
        runner = ResilientRunner(step_fn, data.batch, ck,
                                 ckpt_every=args.ckpt_every,
                                 straggler=StragglerMonitor())
        t0 = time.time()
        state, rep = runner.run(state, args.steps)
        dt = time.time() - t0
        print(f"ran {rep.steps_run} steps in {dt:.1f}s "
              f"({rep.checkpoints} ckpts, {rep.restores} restores, "
              f"{rep.stragglers} stragglers)")
        print("final metrics:", rep.final_metrics)
    else:
        t0 = time.time()
        for i in range(args.steps):
            batch = data.batch(i)
            state, metrics = step_fn(state, i, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
