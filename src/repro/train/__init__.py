from repro.train.step import make_train_step, make_train_step_compressed
