"""Training step: loss + grad (+ microbatch accumulation) + optimizer update.

Two variants:

* `make_train_step` — standard pjit path. Gradients are implicitly
  reduce-scattered/all-reduced by GSPMD according to the param shardings
  (FSDP: grads arrive sharded like params). Microbatch gradient accumulation
  runs as a `lax.scan` so the dispatched MoE buffers and attention
  activations are sized by the microbatch, not the global batch.

* `make_train_step_compressed` — the beyond-paper variant: the whole step
  runs inside `jax.shard_map(axis_names=dp_axes)` with params replicated
  across the data axis, and gradient exchange is the 1-bit majority-vote
  all-reduce (`optim/signum.py`) — the Buddy TRA primitive as the collective
  reduction operator.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.sharding import axis_rules
from repro.optim.optimizers import Optimizer, clip_by_global_norm


def _accum_reshape(batch, accum: int):
    def r(x):
        assert x.shape[0] % accum == 0, (x.shape, accum)
        return x.reshape(accum, x.shape[0] // accum, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(bundle, optimizer: Optimizer, grad_accum: int = 1,
                    clip: float = 1.0) -> Callable:
    """Returns train_step(params, opt_state, step, batch) ->
    (params, opt_state, metrics)."""

    def loss_fn(p, mb):
        loss, metrics = bundle.loss(p, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, step, batch):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs = _accum_reshape(batch, grad_accum)

            def body(carry, mb):
                acc, lsum = carry
                (l, _), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype),
                                   acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, lsum), _ = jax.lax.scan(body, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum
            metrics = {}
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        out = {"loss": loss, "grad_norm": gnorm}
        out.update({k: v for k, v in metrics.items()})
        return params, opt_state, out

    return train_step


def make_train_step_compressed(bundle, optimizer: Optimizer, mesh: Mesh,
                               dp_axes: Tuple[str, ...] = ("data",),
                               batch_logical: Optional[Dict] = None,
                               grad_accum: int = 1, clip: float = 1.0
                               ) -> Callable:
    """signum/majority-vote step inside shard_map over the DP axes.

    Params must be replicated across dp_axes (DP_RULES resolution — the
    model axis stays GSPMD-auto inside the shard_map region). The optimizer
    should be `signum(..., axis_name=dp_axes)`.
    """
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def loss_fn(p, mb):
        loss, metrics = bundle.loss(p, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def inner(params, opt_state, step, batch):
        # Inside the manual region, with_sharding_constraint cannot be
        # applied to values that vary over the manual dp axes (jax vma
        # typing), so logical constraints are disabled; the model axis is
        # still GSPMD-auto and propagates from the param shardings.
        with axis_rules(None):
            return _inner_body(params, opt_state, step, batch)

    def _inner_body(params, opt_state, step, batch):
        if grad_accum == 1:
            (loss, _), grads = grad_fn(params, batch)
        else:
            mbs = _accum_reshape(batch, grad_accum)

            def body(carry, mb):
                acc, lsum = carry
                (l, _), g = grad_fn(params, mb)
                return (jax.tree.map(lambda a, gg: a + gg.astype(a.dtype),
                                     acc, g), lsum + l), None

            # seed the accumulator from microbatch 0 (a pcast'd zeros carry
            # trips an XLA:CPU AllReducePromotion bug), scan the rest.
            mb0 = jax.tree.map(lambda x: x[0], mbs)
            rest = jax.tree.map(lambda x: x[1:], mbs)
            (l0, _), g0 = grad_fn(params, mb0)
            g0 = jax.tree.map(lambda g: g.astype(jnp.float32), g0)
            (grads, lsum), _ = jax.lax.scan(body, (g0, l0), rest)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum
        # NOTE: no psum of grads — the 1-bit majority exchange inside
        # optimizer.update is the only cross-DP gradient communication.
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        loss = jax.lax.pmean(loss, dp_spec)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    def train_step(params, opt_state, step, batch):
        batch_specs = jax.tree.map(lambda _: P(dp_spec), batch)
        rep = P()
        f = jax.shard_map(
            functools.partial(inner),
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: rep, params),
                      jax.tree.map(lambda _: rep, opt_state),
                      rep, batch_specs),
            out_specs=(jax.tree.map(lambda _: rep, params),
                       jax.tree.map(lambda _: rep, opt_state),
                       {"loss": rep, "grad_norm": rep}),
            # check_vma=False: the majority-vote result is replicated across
            # dp axes by construction (all_gather), which the vma type
            # system cannot express (no varying->invariant cast). The eager
            # check_vma=False dispatch path has a jax-0.8 bug (_unmatch dst
            # names every mesh axis), so train_step must stay jit-wrapped.
            axis_names=set(dp_axes), check_vma=False)
        return f(params, opt_state, step, batch)

    # shard_map with inner closed_call (remat/scan) requires a jit wrapper
    return jax.jit(train_step)
