from repro.data.pipeline import SyntheticLM, host_shard
from repro.data.bitmap_filter import CorpusCatalog, build_filter
