"""Bitmap-index corpus curation — the paper's §8.1/§8.2 machinery as a
training-data pipeline stage.

A corpus catalog keeps one packed bitmap per document attribute (language,
quality tier, dedup-canonical, toxicity flag, ...) plus BitWeaving-V vertical
columns for integer metadata (token counts). A filter expression is compiled
to bulk bitwise ops over the packed bitmaps (AND/OR/NOT — on hardware these
are Buddy AAP programs; here the fused TPU kernels) and BitWeaving range
scans, yielding the eligible-document bitmap that drives sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitplane import pack_bits, unpack_bits
from repro.ops.bitwise import bitwise_and, bitwise_not
from repro.ops.popcount import popcount_words
from repro.ops.predicate import VerticalColumn


@dataclasses.dataclass
class CorpusCatalog:
    """n_docs documents with boolean attribute bitmaps and integer columns."""

    attrs: Dict[str, jax.Array]            # name -> (n_words,) uint32 packed
    columns: Dict[str, VerticalColumn]     # name -> vertical int column
    n_docs: int

    @classmethod
    def synthetic(cls, key, n_docs: int,
                  attr_p: Optional[Dict[str, float]] = None,
                  token_bits: int = 12) -> "CorpusCatalog":
        attr_p = attr_p or {"lang_en": 0.6, "quality_hi": 0.3,
                            "dedup_canonical": 0.8, "toxic": 0.05}
        keys = jax.random.split(key, len(attr_p) + 1)
        attrs = {name: pack_bits(jax.random.bernoulli(k, p, (n_docs,)))
                 for (name, p), k in zip(attr_p.items(), keys[:-1])}
        n_tokens = jax.random.randint(keys[-1], (n_docs,), 0,
                                      (1 << token_bits) - 1)
        cols = {"n_tokens": VerticalColumn.encode(n_tokens, token_bits)}
        return cls(attrs, cols, n_docs)


def build_filter(cat: CorpusCatalog,
                 require: Sequence[str] = (),
                 exclude: Sequence[str] = (),
                 ranges: Optional[Dict[str, Tuple[int, int]]] = None
                 ) -> Tuple[jax.Array, int]:
    """Compile and evaluate a filter; returns (packed eligibility bitmap,
    n_eligible). `require`: attributes that must be 1; `exclude`: must be 0;
    `ranges`: integer column lo <= v <= hi (BitWeaving scan)."""
    acc = None

    def et(a, b):
        return b if a is None else bitwise_and(a, b)

    for name in require:
        acc = et(acc, cat.attrs[name])
    for name in exclude:
        acc = et(acc, bitwise_not(cat.attrs[name]))
    for name, (lo, hi) in (ranges or {}).items():
        acc = et(acc, cat.columns[name].scan(lo, hi).words)
    if acc is None:
        acc = jnp.full(((cat.n_docs + 31) // 32,), 0xFFFFFFFF, jnp.uint32)
    # mask tail padding
    n_valid = int(popcount_words(_mask_tail(acc, cat.n_docs)).sum())
    return acc, n_valid


def _mask_tail(packed: jax.Array, n: int) -> jax.Array:
    nw = packed.shape[-1]
    full_bits = nw * 32
    if full_bits == n:
        return packed
    idx = jnp.arange(nw) * 32
    bits_here = jnp.clip(n - idx, 0, 32)
    mask = jnp.where(bits_here >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << bits_here.astype(jnp.uint32)) - 1)
    return packed & mask


def eligible_indices(packed: jax.Array, n_docs: int) -> np.ndarray:
    """Unpack the eligibility bitmap into document indices (host-side)."""
    bits = np.asarray(unpack_bits(packed, n_docs))
    return np.nonzero(bits)[0]


def sample_eligible(key, packed: jax.Array, n_docs: int, batch: int
                    ) -> jax.Array:
    """Uniformly sample `batch` eligible document ids (jit-friendly:
    gumbel-top-k over the eligibility mask)."""
    bits = unpack_bits(packed, n_docs).astype(jnp.float32)
    g = jax.random.gumbel(key, (n_docs,))
    scored = jnp.where(bits > 0, g, -jnp.inf)
    _, idx = jax.lax.top_k(scored, batch)
    return idx.astype(jnp.int32)
