"""Deterministic synthetic data pipeline.

Batches are pure functions of (seed, step): restart/elastic-resume replays
the exact token stream with no iterator state to checkpoint beyond the step
counter. `host_shard` carves the per-host slice for multi-host deployment
(each host feeds its addressable devices; under a single-process dry run it
is the identity).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLM:
    """Markov-ish synthetic LM stream: token t+1 depends on token t plus
    step-keyed noise, so models can actually reduce loss on it (used by the
    end-to-end training convergence tests and examples)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_frontend_tokens: int = 0
    frontend_dim: int = 0
    frontend_name: str = ""

    def batch(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        base = jax.random.randint(k1, (B, 1), 0, V)
        drift = jax.random.randint(k2, (B, S), 0, 7)
        toks = (base + jnp.cumsum(drift, axis=1)) % V
        toks = toks.astype(jnp.int32)
        batch = {
            "tokens": toks,
            "labels": jnp.roll(toks, -1, axis=1).astype(jnp.int32),
            "mask": jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0),
        }
        if self.frontend_name:
            batch[self.frontend_name] = jax.random.normal(
                k3, (B, self.n_frontend_tokens, self.frontend_dim),
                jnp.bfloat16)
        return batch

    @classmethod
    def for_cell(cls, cfg: ModelConfig, shape: ShapeConfig,
                 seed: int = 0) -> "SyntheticLM":
        name = ""
        if cfg.frontend:
            name = "frames" if cfg.frontend == "audio" else "patches"
        return cls(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                   global_batch=shape.global_batch, seed=seed,
                   n_frontend_tokens=cfg.n_frontend_tokens,
                   frontend_dim=cfg.frontend_dim or cfg.d_model,
                   frontend_name=name)


def host_shard(batch: Dict[str, Any], host_id: int = 0, n_hosts: int = 1
               ) -> Dict[str, Any]:
    """Slice the per-host portion of a global batch (leading axis)."""
    if n_hosts == 1:
        return batch

    def s(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return jax.tree.map(s, batch)
