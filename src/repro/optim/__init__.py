from repro.optim.optimizers import (Optimizer, adafactor, adamw,
                                    clip_by_global_norm, sgd)
from repro.optim.schedules import constant, warmup_cosine
from repro.optim.signum import (majority_allreduce, pack_tree, signum,
                                unpack_tree)
