"""Majority-vote 1-bit signSGD ("signum") — the paper's TRA primitive lifted
to the data-parallel collective.

Buddy-RAM's triple-row activation computes bitwise MAJ over rows sharing a
sense amplifier. SignSGD with majority vote [Bernstein et al., 2018]
aggregates worker gradients as the bitwise majority of their sign planes —
the *same reduction operator*, applied across the mesh "data" axis instead of
across DRAM rows. Our implementation:

  1. per-worker: u = grad + error_feedback;  s = packed sign bits (32:1,
     `kernels/signpack.py`);  scale = pmean(mean|u|)  (one scalar/tensor)
  2. bandwidth-optimal compressed all-reduce (`majority_allreduce`):
     all_to_all the packed planes (each worker owns 1/D of the words),
     majority-of-D with the CSA bit-plane kernel (`kernels/majority.py` —
     digital TRA), all_gather the result. Bytes on the wire per chip:
     ~N/8 + N/8 vs 4N for an f32 ring all-reduce -> ~16x collective-byte cut.
  3. update: p -= lr * (maj_sign * scale + wd * p); error feedback keeps the
     quantization residual local: e = u - scale * sign(u).

Used as the beyond-paper §Perf lever on the collective-bound hillclimb cell,
inside a `jax.shard_map(axis_names={"data"})` region (model axis stays auto).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.optim.optimizers import Optimizer

# --------------------------------------------------------------------------
# pack/unpack a pytree into 2-D packed sign planes
# --------------------------------------------------------------------------


def _pad32(n: int) -> int:
    return (n + 31) // 32 * 32


def pack_tree(tree, use_kernel: bool = True):
    """Tree of float arrays -> (packed (1, W) uint32, meta for unpack)."""
    leaves, treedef = jax.tree.flatten(tree)
    flat = [l.reshape(-1).astype(jnp.float32) for l in leaves]
    sizes = [f.shape[0] for f in flat]
    cat = jnp.concatenate(flat) if len(flat) > 1 else flat[0]
    n = cat.shape[0]
    npad = _pad32(n)
    if npad != n:
        cat = jnp.pad(cat, (0, npad - n))
    packer = kops.pack_signs if use_kernel else kref.pack_signs
    packed = packer(cat.reshape(1, npad))
    meta = (treedef, sizes, [l.shape for l in leaves],
            [l.dtype for l in leaves], n)
    return packed, meta


def unpack_tree(packed, meta, use_kernel: bool = True):
    """(1, W) packed signs -> tree of {-1,+1} arrays shaped like original."""
    treedef, sizes, shapes, dtypes, n = meta
    unpacker = kops.unpack_signs if use_kernel else kref.unpack_signs
    flat = unpacker(packed).reshape(-1)[:n]
    out, off = [], 0
    for sz, shp, dt in zip(sizes, shapes, dtypes):
        out.append(flat[off:off + sz].reshape(shp).astype(dt))
        off += sz
    return jax.tree.unflatten(treedef, out)


# --------------------------------------------------------------------------
# compressed majority all-reduce (inside shard_map over `axis_name`)
# --------------------------------------------------------------------------

def majority_allreduce(packed: jax.Array, axis_name: str,
                       use_kernel: bool = True) -> jax.Array:
    """Bitwise-majority all-reduce of packed sign planes.

    packed: (1, W) uint32 per worker. Phase 1: all_to_all so worker d owns
    words [d*W/D:(d+1)*W/D] from every worker. Phase 2: majority-of-D via the
    CSA bit-plane kernel (digital TRA). Phase 3: all_gather the reduced shard.
    """
    D = jax.lax.psum(1, axis_name)
    W = packed.shape[-1]
    Wp = (W + D - 1) // D * D
    if Wp != W:
        packed = jnp.pad(packed, ((0, 0), (0, Wp - W)))
    shards = packed.reshape(D, Wp // D)
    # worker d receives everyone's shard d: (D, Wp//D)
    recv = jax.lax.all_to_all(shards[:, None, :], axis_name,
                              split_axis=0, concat_axis=0)[:, 0, :]
    # recv elements arrive as (D, Wp//D): axis 0 = source worker
    maj_fn = kops.majority if use_kernel else kref.majority_k
    mine = maj_fn(recv[:, None, :])            # (1, Wp//D) majority-of-D
    full = jax.lax.all_gather(mine[0], axis_name, tiled=True)  # (Wp,)
    return full[None, :W]


# --------------------------------------------------------------------------
# the optimizer
# --------------------------------------------------------------------------

def signum(lr_fn, momentum: float = 0.9, weight_decay: float = 0.0,
           axis_name: Optional[str] = None, use_kernel: bool = True,
           error_feedback: bool = True) -> Optimizer:
    """Majority-vote signSGD. If axis_name is None the majority degenerates
    to a local sign step (single worker); with axis_name set it must run
    inside shard_map(axis_names={axis_name, ...})."""

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        st = {"mu": jax.tree.map(z, params)}
        if error_feedback:
            st["err"] = jax.tree.map(z, params)
        return st

    def update(grads, state, params, step):
        lr = lr_fn(step)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if error_feedback:
            u = jax.tree.map(lambda g, e: g + e, g32, state["err"])
        else:
            u = g32
        scales = jax.tree.map(lambda x: jnp.mean(jnp.abs(x)), u)
        if axis_name is not None:
            scales = jax.tree.map(
                lambda s: jax.lax.pmean(s, axis_name), scales)
            packed, meta = pack_tree(u, use_kernel)
            packed = majority_allreduce(packed, axis_name, use_kernel)
            signs = unpack_tree(packed, meta, use_kernel)
        else:
            signs = jax.tree.map(
                lambda x: jnp.where(x >= 0, 1.0, -1.0), u)
        if error_feedback:
            err = jax.tree.map(lambda x, s, sc: x - sc * s, u, signs, scales)
            state = dict(state, err=err)
        mu = jax.tree.map(lambda m, s, sc: momentum * m + sc * s,
                          state["mu"], signs, scales)
        params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr *
                          (m + weight_decay * p.astype(jnp.float32))
                          ).astype(p.dtype), params, mu)
        return params, dict(state, mu=mu)

    return Optimizer(init, update, "signum")
