"""Minimal optax-style optimizers (optax is not vendored in this container).

An `Optimizer` is (init, update) where update returns (new_params, new_state).
State trees are sharded like the params they mirror (the launcher derives
their shardings from the param logical specs), so AdamW here is ZeRO-style:
with FSDP-sharded params the moments are automatically FSDP-sharded too.

`adafactor` provides factored second moments (row/col statistics) for the
largest assigned architectures (kimi-k2 1T, llama4-maverick 400B), where
full Adam moments cannot fit the single-pod HBM budget — see DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable        # params -> state
    update: Callable      # (grads, state, params, step) -> (params, state)
    name: str = "opt"


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def sgd(lr_fn, momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p), params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                          state["mu"], grads)
        params = jax.tree.map(
            lambda p, m: (p - lr * (m + weight_decay * p.astype(m.dtype))
                          .astype(p.dtype)).astype(p.dtype), params, mu)
        return params, {"mu": mu}

    return Optimizer(init, update, "sgd")


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            p32 = p.astype(jnp.float32)
            return (p32 - lr * (u + weight_decay * p32)).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return params, {"m": m, "v": v}

    return Optimizer(init, update, "adamw")


def adafactor(lr_fn, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0
              ) -> Optimizer:
    """Factored second moments: O(r+c) state for matrices, O(n) for vectors.
    No first moment -> 1/6 the optimizer bytes of Adam(f32)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                r = jnp.zeros(p.shape[:-1], jnp.float32)
                c = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"r": r, "c": c}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                r = beta * s["r"] + (1 - beta) * g2.mean(axis=-1)
                c = beta * s["c"] + (1 - beta) * g2.mean(axis=-2)
                denom = (r[..., None] * c[..., None, :]
                         / jnp.maximum(r.mean(axis=-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(denom + eps)
                ns = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            p32 = p.astype(jnp.float32)
            return (p32 - lr * (u + weight_decay * p32)).astype(p.dtype), ns

        out = jax.tree.map(upd, params, grads, state["f"],
                           is_leaf=lambda x: isinstance(x, dict) and
                           set(x) <= {"r", "c", "v"})
        params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        f = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return params, {"f": f}

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    if name == "sgd":
        return sgd(lr_fn, **kw)
    if name == "signum":
        from repro.optim.signum import signum
        return signum(lr_fn, **kw)
    raise ValueError(name)
